//! Serving-stack load benchmark (PR6): the coordinator under seeded
//! fault injection at increasing fault rates.  For each rate the
//! closed-loop generator drives the pool and the report records
//! throughput, latency percentiles, shed/retry/fail rates into
//! `BENCH_PR7.json` — the robustness half of the perf trajectory.
//! Since PR7 the percentiles come from the coordinator's mergeable
//! log-bucketed sketch (±1.6% relative error, exact max) and the
//! report gains the p999/max tail columns.
//!
//! The clean row doubles as a correctness gate: with injection off,
//! every request must complete and a spot-checked result must be
//! bit-identical to the golden model run directly.
//!
//! PR9 adds a two-model mixed-traffic section on a heterogeneous
//! golden + chip-sim pool: per-model latency percentiles and the
//! packed-model cache hit rate land in `BENCH_PR9.json`.
//!
//! Run: `cargo bench --bench bench_serve` (add `-- --quick` for the CI
//! smoke subset).

#[path = "harness.rs"]
mod harness;

use harness::{quick_mode, section, JsonReport};
use std::sync::Arc;
use std::time::{Duration, Instant};
use vsa::config::models;
use vsa::config::HwConfig;
use vsa::coordinator::{
    parse_pool, run_load, run_load_single, ChipEngine, Coordinator, CoordinatorConfig, EngineKind,
    FaultEngine, FaultProfile, FaultStats, GoldenEngine, InferenceEngine, LoadSpec, ModelId,
    ModelRegistry, ModelTraffic,
};
use vsa::data::synth;
use vsa::snn::params::DeployedModel;
use vsa::snn::Network;
use vsa::telemetry::{Registry, SpanCollector};

/// Written next to the other cross-PR trajectory files at the repo root.
const REPORT_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR7.json");
const REPORT_PATH_PR9: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR9.json");

const MODEL: &str = "tiny";
const STEPS: usize = 4;
const SEED: u64 = 7;
const WORKERS: usize = 2;
const BATCH: usize = 8;
const SUBMITTERS: usize = 4;
const FAULT_RATES: [f64; 3] = [0.0, 0.01, 0.10];

fn tiny_model() -> DeployedModel {
    let spec = models::by_name(MODEL, STEPS).expect("tiny model spec");
    DeployedModel::synthesize(&spec, 42)
}

fn start_pool(
    fault_rate: f64,
    fstats: &Arc<FaultStats>,
    spans: Option<Arc<SpanCollector>>,
) -> (Coordinator, ModelId) {
    let profile = FaultProfile::mixed(fault_rate, Duration::from_millis(1));
    let cfg = CoordinatorConfig {
        workers: WORKERS,
        max_batch: BATCH,
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    let (reg, m) = ModelRegistry::single(tiny_model());
    let regc = Arc::clone(&reg);
    let coord = Coordinator::start_with_spans(cfg, reg, spans, {
        let fstats = Arc::clone(fstats);
        move |w| -> Box<dyn InferenceEngine> {
            let inner = Box::new(GoldenEngine::new(Arc::clone(&regc), BATCH));
            let seed_w = FaultEngine::seed_for(SEED, w);
            Box::new(FaultEngine::with_stats(inner, profile, seed_w, Arc::clone(&fstats)))
        }
    });
    (coord, m)
}

fn main() {
    let quick = quick_mode();
    let requests = if quick { 200 } else { 4000 };
    let samples = synth::tiny_like(SEED, 0, 32);
    let images: Vec<Vec<u8>> = samples.into_iter().map(|s| s.image).collect();
    let mut report = JsonReport::new();

    section("serving under fault injection");
    println!(
        "model {MODEL} (T={STEPS}), {WORKERS} workers, batch <= {BATCH}, \
         {SUBMITTERS} submitters, {requests} requests per rate"
    );
    for rate in FAULT_RATES {
        let fstats = Arc::new(FaultStats::default());
        let (coord, m) = start_pool(rate, &fstats, None);

        if rate == 0.0 {
            // Correctness gate: a served result is bit-identical to the
            // golden model invoked directly.
            let reference = Network::new(tiny_model());
            let direct = reference.infer_u8(&images[0]);
            let served = coord.infer_blocking(m, images[0].clone()).expect("clean serve");
            assert_eq!(served.logits, direct, "served result must be bit-identical");
        }

        let spec = LoadSpec { requests, submitters: SUBMITTERS, submit_wait: None };
        let load = run_load_single(&coord, m, &images, &spec);
        let stats = coord.shutdown();

        assert_eq!(load.total(), requests as u64, "every request tallied exactly once");
        assert_eq!(
            stats.completed + stats.failed + stats.shed,
            stats.submitted,
            "coordinator counters balance"
        );
        if rate == 0.0 {
            assert_eq!(load.ok, requests as u64, "clean run: everything completes");
            assert_eq!(stats.failed, 0, "clean run: no failures");
            assert_eq!(stats.shed, 0, "clean run: no shedding");
        }

        let n = requests as f64;
        let shed_rate = stats.shed as f64 / n;
        let fail_rate = stats.failed as f64 / n;
        let retry_rate = stats.retries as f64 / n;
        println!("\nfault rate {:.1}%:", rate * 100.0);
        println!("  {}", load.render());
        println!(
            "  injected {} faults over {} engine calls; {} retries, {} restarts",
            fstats.injected(),
            fstats.calls.load(std::sync::atomic::Ordering::Relaxed),
            stats.retries,
            stats.worker_restarts
        );
        println!(
            "  throughput {:.1} req/s   p50 {:.3} ms   p99 {:.3} ms   p999 {:.3} ms   \
             max {:.3} ms",
            stats.throughput_rps,
            stats.latency_ms_p50,
            stats.latency_ms_p99,
            stats.latency_ms_p999,
            stats.latency_ms_max
        );
        for line in stats.stages.render().lines() {
            println!("  {line}");
        }
        report.serve(
            MODEL,
            rate,
            stats.throughput_rps,
            stats.latency_ms_p50,
            stats.latency_ms_p99,
            stats.latency_ms_p999,
            stats.latency_ms_max,
            shed_rate,
            retry_rate,
            fail_rate,
        );
    }

    // Span-tracing overhead (PR8): the same clean load with per-request
    // span trees on — throughput should be indistinguishable (recording
    // is a ring write; the mutex is only taken at flush).
    section("span tracing overhead (clean run)");
    {
        let spans = SpanCollector::new();
        let fstats = Arc::new(FaultStats::default());
        let (coord, m) = start_pool(0.0, &fstats, Some(Arc::clone(&spans)));
        let spec = LoadSpec { requests, submitters: SUBMITTERS, submit_wait: None };
        let t0 = Instant::now();
        let load = run_load_single(&coord, m, &images, &spec);
        let stats = coord.shutdown();
        let wall = t0.elapsed();
        assert_eq!(load.ok, requests as u64, "traced clean run: everything completes");
        let sheet = spans.sheet();
        sheet.check_nesting().expect("request trees nest");
        let export = sheet.to_chrome_json();
        println!(
            "  {requests} requests in {:.1} ms with tracing on ({:.1} req/s)",
            wall.as_secs_f64() * 1e3,
            stats.throughput_rps
        );
        println!(
            "  {} spans recorded ({} dropped), Chrome export {:.1} KB",
            sheet.len(),
            sheet.dropped,
            export.len() as f64 / 1024.0
        );
    }
    report.write(REPORT_PATH);

    // Two-model mixed traffic on a heterogeneous pool (PR9): tiny and
    // mnist interleave through the same queue, models never share a
    // batch, and each worker's bounded LRU keeps both models packed.
    section("multi-model mixed traffic (golden + chip-sim pool)");
    let mut report9 = JsonReport::new();
    {
        const POOL_SPEC: &str = "golden:3,chip-sim:1";
        let mix_requests = if quick { 200 } else { 2000 };
        let mut registry = ModelRegistry::new();
        let tiny_id = registry.register("tiny", tiny_model()).unwrap();
        let mnist =
            DeployedModel::synthesize(&models::by_name("mnist", 2).expect("mnist spec"), 43);
        let mnist_id = registry.register("mnist", mnist).unwrap();
        let registry = Arc::new(registry);

        let pool = parse_pool(POOL_SPEC).unwrap();
        let cfg = CoordinatorConfig {
            workers: pool.len(),
            max_batch: BATCH,
            queue_depth: 64,
            ..CoordinatorConfig::default()
        };
        let regc = Arc::clone(&registry);
        let mut coord = Coordinator::start(cfg, Arc::clone(&registry), move |w| {
            let e: Box<dyn InferenceEngine> = match pool[w] {
                EngineKind::Golden => Box::new(GoldenEngine::new(Arc::clone(&regc), BATCH)),
                EngineKind::ChipSim => {
                    Box::new(ChipEngine::new(HwConfig::default(), Arc::clone(&regc), BATCH))
                }
            };
            e
        });

        let traffic = vec![
            ModelTraffic { model: tiny_id, weight: 1, images: images.clone() },
            ModelTraffic {
                model: mnist_id,
                weight: 1,
                images: synth::mnist_like(SEED, 0, 32).into_iter().map(|s| s.image).collect(),
            },
        ];
        let spec = LoadSpec { requests: mix_requests, submitters: SUBMITTERS, submit_wait: None };
        let t0 = Instant::now();
        let load = run_load(&coord, &traffic, &spec);
        let wall = t0.elapsed();
        coord.drain();

        let treg = Registry::new();
        coord.export_into(&treg, "serve");
        let snap = treg.snapshot();
        let cache = coord.cache_totals();
        let hit_rate = if cache.lookups > 0 {
            cache.hits as f64 / cache.lookups as f64
        } else {
            0.0
        };
        assert_eq!(load.ok, mix_requests as u64, "clean mixed run: everything completes");
        assert_eq!(cache.hits + cache.misses, cache.lookups, "cache counters balance");

        println!(
            "  {} requests over 2 models on pool [{}] in {:.1} ms ({:.1} req/s)",
            mix_requests,
            POOL_SPEC,
            wall.as_secs_f64() * 1e3,
            mix_requests as f64 / wall.as_secs_f64()
        );
        for name in ["tiny", "mnist"] {
            let done = snap.counters[&format!("serve.model.{name}.completed")];
            let sk = &snap.sketches[&format!("serve.model.{name}.latency")];
            println!(
                "  {:<6} completed {:>5}   p50 {:.3} ms   p99 {:.3} ms",
                name,
                done,
                sk.quantile_ms(0.50),
                sk.quantile_ms(0.99)
            );
            report9.serve_model(
                name,
                POOL_SPEC,
                done,
                sk.quantile_ms(0.50),
                sk.quantile_ms(0.99),
                hit_rate,
            );
        }
        println!(
            "  model cache: {} lookups, {} hits, {} misses, {} evictions ({:.1}% hit)",
            cache.lookups,
            cache.hits,
            cache.misses,
            cache.evictions,
            hit_rate * 100.0
        );
        let stats = coord.shutdown();
        assert_eq!(
            stats.completed + stats.failed + stats.shed,
            stats.submitted,
            "mixed-run counters balance"
        );
    }
    report9.write(REPORT_PATH_PR9);
}

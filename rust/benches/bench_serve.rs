//! Serving-stack load benchmark (PR6): the coordinator under seeded
//! fault injection at increasing fault rates.  For each rate the
//! closed-loop generator drives the pool and the report records
//! throughput, latency percentiles, shed/retry/fail rates into
//! `BENCH_PR7.json` — the robustness half of the perf trajectory.
//! Since PR7 the percentiles come from the coordinator's mergeable
//! log-bucketed sketch (±1.6% relative error, exact max) and the
//! report gains the p999/max tail columns.
//!
//! The clean row doubles as a correctness gate: with injection off,
//! every request must complete and a spot-checked result must be
//! bit-identical to the golden model run directly.
//!
//! Run: `cargo bench --bench bench_serve` (add `-- --quick` for the CI
//! smoke subset).

#[path = "harness.rs"]
mod harness;

use harness::{quick_mode, section, JsonReport};
use std::sync::Arc;
use std::time::{Duration, Instant};
use vsa::config::models;
use vsa::coordinator::{
    run_load, Coordinator, CoordinatorConfig, FaultEngine, FaultProfile, FaultStats, GoldenEngine,
    InferenceEngine, LoadSpec,
};
use vsa::data::synth;
use vsa::snn::params::DeployedModel;
use vsa::snn::Network;
use vsa::telemetry::SpanCollector;

/// Written next to the other cross-PR trajectory files at the repo root.
const REPORT_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR7.json");

const MODEL: &str = "tiny";
const STEPS: usize = 4;
const SEED: u64 = 7;
const WORKERS: usize = 2;
const BATCH: usize = 8;
const SUBMITTERS: usize = 4;
const FAULT_RATES: [f64; 3] = [0.0, 0.01, 0.10];

fn tiny_net() -> Network {
    let spec = models::by_name(MODEL, STEPS).expect("tiny model spec");
    Network::new(DeployedModel::synthesize(&spec, 42))
}

fn start_pool(
    fault_rate: f64,
    fstats: &Arc<FaultStats>,
    spans: Option<Arc<SpanCollector>>,
) -> Coordinator {
    let profile = FaultProfile::mixed(fault_rate, Duration::from_millis(1));
    let cfg = CoordinatorConfig {
        workers: WORKERS,
        max_batch: BATCH,
        queue_depth: 64,
        ..CoordinatorConfig::default()
    };
    Coordinator::start_with_spans(cfg, spans, {
        let fstats = Arc::clone(fstats);
        move |w| -> Box<dyn InferenceEngine> {
            let inner = Box::new(GoldenEngine::new(tiny_net(), BATCH));
            let seed_w = FaultEngine::seed_for(SEED, w);
            Box::new(FaultEngine::with_stats(inner, profile, seed_w, Arc::clone(&fstats)))
        }
    })
}

fn main() {
    let quick = quick_mode();
    let requests = if quick { 200 } else { 4000 };
    let samples = synth::tiny_like(SEED, 0, 32);
    let images: Vec<Vec<u8>> = samples.into_iter().map(|s| s.image).collect();
    let mut report = JsonReport::new();

    section("serving under fault injection");
    println!(
        "model {MODEL} (T={STEPS}), {WORKERS} workers, batch <= {BATCH}, \
         {SUBMITTERS} submitters, {requests} requests per rate"
    );
    for rate in FAULT_RATES {
        let fstats = Arc::new(FaultStats::default());
        let coord = start_pool(rate, &fstats, None);

        if rate == 0.0 {
            // Correctness gate: a served result is bit-identical to the
            // golden model invoked directly.
            let reference = tiny_net();
            let direct = reference.infer_u8(&images[0]);
            let served = coord.infer_blocking(images[0].clone()).expect("clean serve");
            assert_eq!(served.logits, direct, "served result must be bit-identical");
        }

        let spec = LoadSpec { requests, submitters: SUBMITTERS, submit_wait: None };
        let load = run_load(&coord, &images, &spec);
        let stats = coord.shutdown();

        assert_eq!(load.total(), requests as u64, "every request tallied exactly once");
        assert_eq!(
            stats.completed + stats.failed + stats.shed,
            stats.submitted,
            "coordinator counters balance"
        );
        if rate == 0.0 {
            assert_eq!(load.ok, requests as u64, "clean run: everything completes");
            assert_eq!(stats.failed, 0, "clean run: no failures");
            assert_eq!(stats.shed, 0, "clean run: no shedding");
        }

        let n = requests as f64;
        let shed_rate = stats.shed as f64 / n;
        let fail_rate = stats.failed as f64 / n;
        let retry_rate = stats.retries as f64 / n;
        println!("\nfault rate {:.1}%:", rate * 100.0);
        println!("  {}", load.render());
        println!(
            "  injected {} faults over {} engine calls; {} retries, {} restarts",
            fstats.injected(),
            fstats.calls.load(std::sync::atomic::Ordering::Relaxed),
            stats.retries,
            stats.worker_restarts
        );
        println!(
            "  throughput {:.1} req/s   p50 {:.3} ms   p99 {:.3} ms   p999 {:.3} ms   \
             max {:.3} ms",
            stats.throughput_rps,
            stats.latency_ms_p50,
            stats.latency_ms_p99,
            stats.latency_ms_p999,
            stats.latency_ms_max
        );
        for line in stats.stages.render().lines() {
            println!("  {line}");
        }
        report.serve(
            MODEL,
            rate,
            stats.throughput_rps,
            stats.latency_ms_p50,
            stats.latency_ms_p99,
            stats.latency_ms_p999,
            stats.latency_ms_max,
            shed_rate,
            retry_rate,
            fail_rate,
        );
    }

    // Span-tracing overhead (PR8): the same clean load with per-request
    // span trees on — throughput should be indistinguishable (recording
    // is a ring write; the mutex is only taken at flush).
    section("span tracing overhead (clean run)");
    {
        let spans = SpanCollector::new();
        let fstats = Arc::new(FaultStats::default());
        let coord = start_pool(0.0, &fstats, Some(Arc::clone(&spans)));
        let spec = LoadSpec { requests, submitters: SUBMITTERS, submit_wait: None };
        let t0 = Instant::now();
        let load = run_load(&coord, &images, &spec);
        let stats = coord.shutdown();
        let wall = t0.elapsed();
        assert_eq!(load.ok, requests as u64, "traced clean run: everything completes");
        let sheet = spans.sheet();
        sheet.check_nesting().expect("request trees nest");
        let export = sheet.to_chrome_json();
        println!(
            "  {requests} requests in {:.1} ms with tracing on ({:.1} req/s)",
            wall.as_secs_f64() * 1e3,
            stats.throughput_rps
        );
        println!(
            "  {} spans recorded ({} dropped), Chrome export {:.1} KB",
            sheet.len(),
            sheet.dropped,
            export.len() as f64 / 1024.0
        );
    }
    report.write(REPORT_PATH);
}

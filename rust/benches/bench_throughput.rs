//! Throughput / utilization benches for the vectorwise dataflow
//! (paper Fig. 5/6 and the "full hardware utilization" claim), plus the
//! elementwise (SpinalFlow-style) comparison and a serving throughput
//! sweep through the coordinator.
//!
//! Run: `cargo bench --bench bench_throughput`

#[path = "harness.rs"]
mod harness;

use harness::{bench, section};
use std::time::Duration;
use vsa::arch::schedule::{LayerPlan, PlanKind};
use vsa::arch::{Chip, SimMode};
use vsa::baselines::spinalflow::{self, SpinalFlowConfig};
use vsa::config::HwConfig;
use vsa::coordinator::{Coordinator, CoordinatorConfig, GoldenEngine, InferenceEngine};
use vsa::data::synth;
use vsa::snn::Network;

fn conv_plan(c_in: usize, c_out: usize, hw_size: usize) -> LayerPlan {
    LayerPlan {
        kind: PlanKind::Conv,
        c_in,
        c_out,
        k: 3,
        h: hw_size,
        w: hw_size,
        pooled: false,
        model_index: 0,
    }
}

fn main() {
    let hw = HwConfig::default();

    section("vectorwise utilization across layer geometries (Fig. 5/6 claim)");
    println!(
        "  {:>6} {:>6} {:>6} {:>12} {:>10} {:>8}",
        "C_in", "C_out", "HxW", "cycles/step", "GOPS", "util %"
    );
    for (c_in, c_out, s) in [
        (128usize, 128usize, 32usize), // CIFAR early layers: divides evenly
        (192, 192, 16),
        (256, 256, 8),
        (64, 64, 14),  // MNIST: ragged rows (14 % 8 != 0)
        (100, 64, 14), // ragged channels too
        (3, 128, 32),  // thin input without bitplane expansion
    ] {
        let p = conv_plan(c_in, c_out, s);
        let cycles = p.cycles(&hw, 1);
        let util = p.utilization(&hw, 1);
        let gops = util * hw.peak_gops();
        println!(
            "  {c_in:>6} {c_out:>6} {:>6} {cycles:>12} {gops:>10.0} {:>8.1}",
            format!("{s}x{s}"),
            util * 100.0
        );
    }
    println!("  (geometry that divides the 32-block/8-row fabric runs at ~full utilization — the paper's claim; ragged edges show the cost of padding.)");

    section("end-to-end effective throughput per model");
    for (name, path) in [
        ("tiny", "artifacts/tiny_t4.vsaw"),
        ("mnist", "artifacts/mnist_t8.vsaw"),
        ("cifar10", "artifacts/cifar10_t8.vsaw"),
    ] {
        let Ok(net) = Network::from_vsaw_file(path) else {
            eprintln!("  {name}: run `make artifacts`");
            continue;
        };
        let img = &synth::for_model(name, 3, 0, 1)[0].image;
        let r = Chip::new(hw.clone(), SimMode::Fast).run(&net.model, img);
        println!(
            "  {name:<8} {:>10} cycles  {:>8.1} us  {:>6.0} GOPS eff ({:.0}% of peak)",
            r.cycles,
            r.latency_us,
            r.gops,
            r.gops / hw.peak_gops() * 100.0
        );
    }

    section("vectorwise vs elementwise (SpinalFlow-style) on mnist");
    if let Ok(net) = Network::from_vsaw_file("artifacts/mnist_t8.vsaw") {
        let img = &synth::mnist_like(3, 0, 1)[0].image;
        let vsa_r = Chip::new(hw.clone(), SimMode::Fast).run(&net.model, img);
        let sf = spinalflow::run(&SpinalFlowConfig::default(), &net.model, img);
        println!(
            "  VSA:        {:>10} cycles @500MHz = {:>9.1} us  ({:.0} GOPS eff)",
            vsa_r.cycles, vsa_r.latency_us, vsa_r.gops
        );
        println!(
            "  SpinalFlow: {:>10} cycles @200MHz = {:>9.1} us  ({:.1} GOPS eff, {} spikes processed)",
            sf.cycles, sf.latency_us, sf.effective_gops, sf.total_spikes
        );
        println!(
            "  speedup {:.1}x — the paper's elementwise-vs-vectorwise ordering",
            sf.latency_us / vsa_r.latency_us
        );
    }

    section("simulator wall-clock (fast mode)");
    if let Ok(net) = Network::from_vsaw_file("artifacts/mnist_t8.vsaw") {
        let img = &synth::mnist_like(3, 0, 1)[0].image;
        let chip = Chip::new(hw.clone(), SimMode::Fast);
        bench("mnist full-net sim (fast)", 2, 10, || {
            let _ = chip.run(&net.model, img);
        });
        let chip_e = Chip::new(hw.clone(), SimMode::Exact);
        bench("mnist full-net sim (exact)", 0, 1, || {
            let _ = chip_e.run(&net.model, img);
        });
    }

    section("serving throughput vs batch size (coordinator, golden engine)");
    if std::path::Path::new("artifacts/tiny_t4.vsaw").exists() {
        println!("  {:>6} {:>12} {:>10}", "batch", "req/s", "p50 ms");
        for batch in [1usize, 4, 8, 16] {
            let coord = Coordinator::start(
                CoordinatorConfig {
                    workers: 2,
                    max_batch: batch,
                    max_wait: Duration::from_micros(500),
                    queue_depth: 256,
                },
                move |_| {
                    Box::new(GoldenEngine::new(
                        Network::from_vsaw_file("artifacts/tiny_t4.vsaw").unwrap(),
                        batch,
                    )) as Box<dyn InferenceEngine>
                },
            );
            let samples = synth::tiny_like(5, 0, 256);
            let rxs: Vec<_> = samples
                .iter()
                .map(|s| coord.submit(s.image.clone()).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap();
            }
            let stats = coord.shutdown();
            println!(
                "  {batch:>6} {:>12.0} {:>10.3}",
                stats.throughput_rps, stats.latency_ms_p50
            );
        }
    }
}

//! Throughput / utilization benches for the vectorwise dataflow
//! (paper Fig. 5/6 and the "full hardware utilization" claim), plus the
//! elementwise (SpinalFlow-style) comparison and a serving throughput
//! sweep through the coordinator.
//!
//! The headline section measures the **golden-engine hot path before and
//! after the time-batched refactor in the same run** — the per-step
//! engine is frozen in `baselines::golden_stepwise` — and records
//! images/sec for the golden and chip-sim engines in `BENCH_PR1.json`.
//! The PR2 section additionally sweeps the design space (`vsa::dse`),
//! times the chip at the Pareto-best configuration, and appends the rows
//! to `BENCH_PR2.json`.  The PR5 section does for the chip simulator what
//! PR1 did for the golden engine: stepwise (frozen in
//! `baselines::chip_stepwise`) vs time-batched fast mode, reports
//! asserted field-identical in-run, written to `BENCH_PR5.json`.  The
//! PR10 section measures the forced-scalar vs runtime-dispatched
//! AND-popcount kernel flavors and the golden engine's multi-core batch
//! sharding in the same run (logits asserted bit-exact across all
//! paths), written to `BENCH_PR10.json`.
//!
//! Run: `cargo bench --bench bench_throughput` (add `-- --quick` for the
//! CI smoke subset).

#[path = "harness.rs"]
mod harness;

use harness::{bench, quick_mode, section, JsonReport};

/// Repo-root report paths (cargo runs benches with CWD = the package
/// dir).  BENCH_PR1.json keeps the PR1 rows for continuity;
/// BENCH_PR2.json appends the DSE rows — the cross-PR trajectory file.
const REPORT_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR1.json");
const REPORT2_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR2.json");
const REPORT5_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR5.json");
const REPORT10_PATH: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_PR10.json");
use std::sync::Arc;
use std::time::Duration;
use vsa::arch::schedule::{LayerPlan, PlanKind};
use vsa::arch::{Chip, SimMode};
use vsa::baselines::chip_stepwise::StepwiseChip;
use vsa::baselines::golden_stepwise::StepwiseGolden;
use vsa::baselines::spinalflow::{self, SpinalFlowConfig};
use vsa::config::{models, HwConfig};
use vsa::coordinator::{
    Coordinator, CoordinatorConfig, GoldenEngine, InferenceEngine, ModelRegistry,
};
use vsa::data::synth;
use vsa::dse::{self, Candidate, SearchSpace};
use vsa::snn::params::DeployedModel;
use vsa::snn::popcount;
use vsa::snn::{Network, Scratch};

fn conv_plan(c_in: usize, c_out: usize, hw_size: usize) -> LayerPlan {
    LayerPlan {
        kind: PlanKind::Conv,
        c_in,
        c_out,
        k: 3,
        h: hw_size,
        w: hw_size,
        pooled: false,
        model_index: 0,
    }
}

/// Golden hot path before vs after, measured in the same run on
/// synthesized Table-I models (no artifacts needed).
fn golden_before_after(report: &mut JsonReport, quick: bool) {
    section("golden engine: time-batched vs per-step hot path (PR1 tentpole)");
    let cases: &[(&str, usize, usize, usize)] = if quick {
        // (model, T, images, timing iters)
        &[("tiny", 4, 4, 5), ("mnist", 8, 2, 2)]
    } else {
        &[("tiny", 4, 16, 20), ("mnist", 8, 8, 8), ("cifar10", 8, 1, 2)]
    };
    for &(name, t, n_images, iters) in cases {
        let spec = models::by_name(name, t).expect("preset exists");
        let model = DeployedModel::synthesize(&spec, 7);
        let net = Network::new(model.clone());
        let stepwise = StepwiseGolden::new(model);
        let images: Vec<Vec<u8>> = synth::for_model(name, 3, 0, n_images)
            .into_iter()
            .map(|s| s.image)
            .collect();

        // Bit-exactness first: the refactor must not change a single logit.
        let mut scratch = Scratch::new();
        for img in &images {
            assert_eq!(
                net.infer_u8_with(img, &mut scratch),
                stepwise.infer_u8(img),
                "{name}: time-batched logits diverge from the per-step oracle"
            );
        }

        let t_base = bench(&format!("{name}: per-step golden (pre-refactor)"), 1, iters, || {
            for img in &images {
                std::hint::black_box(stepwise.infer_u8(img));
            }
        });
        let t_new = bench(&format!("{name}: time-batched golden (this PR)"), 1, iters, || {
            for img in &images {
                std::hint::black_box(net.infer_u8_with(img, &mut scratch));
            }
        });
        let ips_base = n_images as f64 / (t_base.mean_ms / 1e3);
        let ips_new = n_images as f64 / (t_new.mean_ms / 1e3);
        let speedup = ips_new / ips_base;
        println!(
            "  {name}: {ips_base:.1} -> {ips_new:.1} images/sec ({speedup:.2}x, logits bit-exact)"
        );
        report.throughput(
            "golden_stepwise",
            name,
            ips_base,
            "pre-refactor per-timestep baseline (baselines::golden_stepwise)",
        );
        report.throughput(
            "golden",
            name,
            ips_new,
            "time-batched zero-alloc hot path (snn::Network + Scratch)",
        );
        report.ratio(
            &format!("{name}_golden_speedup"),
            speedup,
            "time-batched vs per-step, same run, bit-exact logits",
        );
    }
}

/// Chip-sim engine wall-clock images/sec, for the cross-engine trajectory.
fn chip_sim_throughput(report: &mut JsonReport, quick: bool) {
    section("chip-sim engine wall-clock (fast mode, synthesized models)");
    let cases: &[(&str, usize, usize)] =
        if quick { &[("tiny", 4, 3)] } else { &[("tiny", 4, 10), ("mnist", 8, 3)] };
    for &(name, t, iters) in cases {
        let spec = models::by_name(name, t).expect("preset exists");
        let model = DeployedModel::synthesize(&spec, 7);
        let img = synth::for_model(name, 3, 0, 1).remove(0).image;
        let chip = Chip::new(HwConfig::default(), SimMode::Fast);
        let timing = bench(&format!("{name}: full-net sim (fast)"), 1, iters, || {
            std::hint::black_box(chip.run(&model, &img));
        });
        let ips = 1.0 / (timing.mean_ms / 1e3);
        report.throughput("chip-sim", name, ips, "cycle-accurate fast mode, wall-clock");
    }
}

/// Chip simulator fast mode before vs after temporal batching (PR5
/// tentpole), measured in the same run on synthesized Table-I models.
/// The per-step engine is frozen in `baselines::chip_stepwise`; the live
/// fast mode packs once per model (cached on the `Chip`) and drives all
/// T steps through the shared time-batched kernels.  Reports are asserted
/// bit-identical (logits + every headline counter) before timing.
fn chip_before_after(report: &mut JsonReport, quick: bool) {
    section("chip sim fast mode: time-batched vs per-step (PR5 tentpole)");
    let cases: &[(&str, usize, usize, usize)] = if quick {
        // (model, T, images, timing iters)
        &[("tiny", 4, 4, 3), ("mnist", 8, 1, 2)]
    } else {
        &[("tiny", 4, 8, 10), ("mnist", 8, 4, 4)]
    };
    for &(name, t, n_images, iters) in cases {
        let spec = models::by_name(name, t).expect("preset exists");
        let model = DeployedModel::synthesize(&spec, 7);
        let images: Vec<Vec<u8>> = synth::for_model(name, 3, 0, n_images)
            .into_iter()
            .map(|s| s.image)
            .collect();
        let chip = Chip::new(HwConfig::default(), SimMode::Fast);
        let stepwise = StepwiseChip::new(HwConfig::default());

        // Bit-exactness first: the refactor must not move a single
        // counter, spike count or logit.
        for img in &images {
            let a = chip.run(&model, img);
            let b = stepwise.run(&model, img);
            assert_eq!(a.logits, b.logits, "{name}: logits diverge");
            assert_eq!(a.cycles, b.cycles, "{name}: cycles diverge");
            assert_eq!(a.pe_ops, b.pe_ops, "{name}: pe_ops diverge");
            assert_eq!(a.dram.total(), b.dram.total(), "{name}: dram diverges");
            assert_eq!(a.sram.total(), b.sram.total(), "{name}: sram diverges");
        }
        assert_eq!(chip.pack_count(), 1, "{name}: batch loop must pack once");

        let t_base = bench(&format!("{name}: per-step chip sim (pre-refactor)"), 1, iters, || {
            for img in &images {
                std::hint::black_box(stepwise.run(&model, img));
            }
        });
        let t_new = bench(&format!("{name}: time-batched chip sim (this PR)"), 1, iters, || {
            for img in &images {
                std::hint::black_box(chip.run(&model, img));
            }
        });
        let ips_base = n_images as f64 / (t_base.mean_ms / 1e3);
        let ips_new = n_images as f64 / (t_new.mean_ms / 1e3);
        let speedup = ips_new / ips_base;
        println!(
            "  {name}: {ips_base:.1} -> {ips_new:.1} images/sec ({speedup:.2}x, \
             reports bit-exact)"
        );
        report.throughput(
            "chip-stepwise",
            name,
            ips_base,
            "pre-refactor per-timestep fast mode (baselines::chip_stepwise)",
        );
        report.throughput(
            "chip-batched",
            name,
            ips_new,
            "time-batched fast mode, packed model cached per Chip (this PR)",
        );
        report.ratio(
            &format!("{name}_chip_speedup"),
            speedup,
            "chip sim stepwise vs time-batched, same run, reports bit-exact",
        );
    }
}

/// PR10: scalar vs vectorized AND-popcount kernels vs multi-core
/// batches, all measured in the same run (BENCH_PR10.json).  The scalar
/// rows pin the kernels to the forced-scalar flavor (exactly what
/// `VSA_FORCE_SCALAR=1` runs); the vector rows use the runtime-dispatched
/// flavor; the multicore rows shard the golden engine's batch over
/// worker threads.  Bit-exactness across all three is asserted before
/// anything is timed — integer popcount sums are order-independent, so
/// none of these paths may move a single logit.
fn pr10_vectorized_and_multicore(report: &mut JsonReport, quick: bool) {
    section("scalar vs vectorized kernels vs multi-core batches (PR10 tentpole)");
    let threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 8);
    let cases: &[(&str, usize, usize, usize)] = if quick {
        // (model, T, images, timing iters)
        &[("tiny", 4, 8, 3)]
    } else {
        &[("tiny", 4, 32, 8), ("mnist", 8, 8, 3)]
    };
    for &(name, t, n_images, iters) in cases {
        let spec = models::by_name(name, t).expect("preset exists");
        let model = DeployedModel::synthesize(&spec, 7);
        let images: Vec<Vec<u8>> = synth::for_model(name, 3, 0, n_images)
            .into_iter()
            .map(|s| s.image)
            .collect();
        let net = Network::new(model.clone());
        let mut scratch = Scratch::new();

        // Bit-exactness first: scalar flavor, dispatched flavor, and the
        // threaded engine batch must agree logit for logit.
        popcount::set_force_scalar(true);
        let scalar_logits: Vec<Vec<i64>> =
            images.iter().map(|i| net.infer_u8_with(i, &mut scratch)).collect();
        popcount::set_force_scalar(false);
        let kernel = popcount::active_kernel();
        let vector_logits: Vec<Vec<i64>> =
            images.iter().map(|i| net.infer_u8_with(i, &mut scratch)).collect();
        assert_eq!(scalar_logits, vector_logits, "{name}: kernel flavors diverge");
        let (reg, mid) = ModelRegistry::single(model.clone());
        let mut engine = GoldenEngine::new(reg, n_images).with_threads(threads);
        assert_eq!(
            engine.infer(mid, &images).expect("threaded batch"),
            vector_logits,
            "{name}: {threads}-thread batch diverges from serial"
        );

        popcount::set_force_scalar(true);
        let t_scalar = bench(&format!("{name}: golden 1-core scalar"), 1, iters, || {
            for img in &images {
                std::hint::black_box(net.infer_u8_with(img, &mut scratch));
            }
        });
        popcount::set_force_scalar(false);
        let t_vector = bench(&format!("{name}: golden 1-core {kernel}"), 1, iters, || {
            for img in &images {
                std::hint::black_box(net.infer_u8_with(img, &mut scratch));
            }
        });
        let t_multi = bench(&format!("{name}: golden {threads}-core batch"), 1, iters, || {
            std::hint::black_box(engine.infer(mid, &images).expect("threaded batch"));
        });
        let ips_scalar = n_images as f64 / (t_scalar.mean_ms / 1e3);
        let ips_vector = n_images as f64 / (t_vector.mean_ms / 1e3);
        let ips_multi = n_images as f64 / (t_multi.mean_ms / 1e3);
        println!(
            "  {name}: {ips_scalar:.1} scalar -> {ips_vector:.1} {kernel} ({:.2}x) -> \
             {ips_multi:.1} on {threads} cores ({:.2}x vs scalar, logits bit-exact)",
            ips_vector / ips_scalar,
            ips_multi / ips_scalar
        );
        report.throughput(
            "golden-scalar",
            name,
            ips_scalar,
            "1 core, forced-scalar AND-popcount kernels (VSA_FORCE_SCALAR=1 flavor)",
        );
        report.throughput(
            "golden-vector",
            name,
            ips_vector,
            &format!("1 core, runtime-dispatched '{kernel}' kernels"),
        );
        report.throughput(
            "golden-multicore",
            name,
            ips_multi,
            &format!("{threads} cores, deterministic batch sharding + '{kernel}' kernels"),
        );
        report.ratio(
            &format!("{name}_golden_vector_speedup_vs_scalar"),
            ips_vector / ips_scalar,
            "single-core kernel speedup, same run, logits bit-exact",
        );
        report.ratio(
            &format!("{name}_golden_multicore_speedup_vs_scalar"),
            ips_multi / ips_scalar,
            &format!("{threads}-core batch vs 1-core scalar, same run, logits bit-exact"),
        );
        report.ratio(
            &format!("{name}_golden_multicore_scaling_vs_vector"),
            ips_multi / ips_vector,
            &format!("{threads}-core batch vs 1-core dispatched kernels"),
        );

        // The chip simulator's fast mode inherits the same kernels
        // through PackedConv/PackedFc — same scalar-vs-vector contract.
        let chip = Chip::new(HwConfig::default(), SimMode::Fast);
        popcount::set_force_scalar(true);
        let chip_scalar = chip.run(&model, &images[0]);
        popcount::set_force_scalar(false);
        let chip_vector = chip.run(&model, &images[0]);
        assert_eq!(
            chip_scalar.logits, chip_vector.logits,
            "{name}: chip fast-mode flavors diverge"
        );
        popcount::set_force_scalar(true);
        let t_chip_scalar = bench(&format!("{name}: chip fast 1-core scalar"), 1, iters, || {
            for img in &images {
                std::hint::black_box(chip.run(&model, img));
            }
        });
        popcount::set_force_scalar(false);
        let t_chip_vector =
            bench(&format!("{name}: chip fast 1-core {kernel}"), 1, iters, || {
                for img in &images {
                    std::hint::black_box(chip.run(&model, img));
                }
            });
        let chips_scalar = n_images as f64 / (t_chip_scalar.mean_ms / 1e3);
        let chips_vector = n_images as f64 / (t_chip_vector.mean_ms / 1e3);
        report.throughput(
            "chip-scalar",
            name,
            chips_scalar,
            "fast mode, forced-scalar kernels (inherited through PackedConv/PackedFc)",
        );
        report.throughput(
            "chip-vector",
            name,
            chips_vector,
            &format!("fast mode, runtime-dispatched '{kernel}' kernels"),
        );
        report.ratio(
            &format!("{name}_chip_vector_speedup_vs_scalar"),
            chips_vector / chips_scalar,
            "chip fast-mode kernel speedup, same run, reports bit-exact",
        );
    }
}

/// Chip throughput at the DSE-selected best configuration (highest-
/// throughput Pareto point of the mnist sweep) next to the published
/// design point — the start of the cross-PR images/sec trajectory the
/// ROADMAP asks for (recorded in BENCH_PR2.json).
fn dse_best_config(report: &mut JsonReport, quick: bool) {
    section("chip at the DSE-selected best config (Pareto sweep, mnist)");
    let mut space = if quick { SearchSpace::tiny() } else { SearchSpace::small() };
    // Pin the sweep to the paper's T so the trajectory compares chips,
    // not time-step counts (lower T does strictly less compute at an
    // accuracy cost the analytic model does not score).
    space.num_steps = vec![Candidate::paper().num_steps];
    let workloads = ["mnist"];
    let cands: Vec<Candidate> = space
        .cartesian()
        .filter(|c| dse::validate(c, &workloads).is_ok())
        .collect();
    let results = dse::evaluate_all(&cands, &workloads, 4);
    let front = dse::frontier(&results);
    let best = &results[front[0]]; // frontier is sorted by throughput desc
    let paper = dse::evaluate_one(&Candidate::paper(), &workloads);
    println!(
        "  space '{}': best frontier point [{}]\n  modeled {:.1} inf/s vs paper point {:.1} inf/s",
        space.name,
        best.candidate.id(),
        best.throughput_ips,
        paper.throughput_ips
    );
    report.throughput(
        "chip-model-paper",
        "mnist",
        paper.throughput_ips,
        "analytic chip model at the published design point",
    );
    report.throughput(
        "chip-model-dse-best",
        "mnist",
        best.throughput_ips,
        &format!("analytic chip model at DSE frontier best [{}]", best.candidate.id()),
    );
    report.ratio(
        "mnist_dse_best_vs_paper",
        best.throughput_ips / paper.throughput_ips,
        "modeled throughput, DSE frontier best vs published design point",
    );

    // Wall-clock of the functional simulator reconfigured to the best
    // point (results stay bit-identical to the golden model; only the
    // timing/traffic counters change with the config).
    let spec = models::by_name("mnist", best.candidate.num_steps).expect("preset exists");
    let model = DeployedModel::synthesize(&spec, 7);
    let img = synth::for_model("mnist", 3, 0, 1).remove(0).image;
    let chip = Chip::new(best.candidate.hw.clone(), SimMode::Fast);
    let iters = if quick { 2 } else { 3 };
    let timing = bench("mnist: full-net sim at DSE best (fast)", 1, iters, || {
        std::hint::black_box(chip.run(&model, &img));
    });
    report.throughput(
        "chip-sim-dse-best",
        "mnist",
        1.0 / (timing.mean_ms / 1e3),
        "cycle-accurate fast mode wall-clock at the DSE-selected config",
    );
}

fn main() {
    let quick = quick_mode();
    let hw = HwConfig::default();
    let mut report = JsonReport::new();

    golden_before_after(&mut report, quick);
    chip_sim_throughput(&mut report, quick);

    // PR5: chip stepwise-vs-batched rows get their own trajectory file.
    let mut report5 = JsonReport::new();
    chip_before_after(&mut report5, quick);
    report5.write(REPORT5_PATH);

    // PR10: scalar/vector/multicore rows in their own trajectory file
    // (runs in quick mode too — it IS the CI evidence for the kernels).
    let mut report10 = JsonReport::new();
    pr10_vectorized_and_multicore(&mut report10, quick);
    report10.write(REPORT10_PATH);

    section("vectorwise utilization across layer geometries (Fig. 5/6 claim)");
    println!(
        "  {:>6} {:>6} {:>6} {:>12} {:>10} {:>8}",
        "C_in", "C_out", "HxW", "cycles/step", "GOPS", "util %"
    );
    for (c_in, c_out, s) in [
        (128usize, 128usize, 32usize), // CIFAR early layers: divides evenly
        (192, 192, 16),
        (256, 256, 8),
        (64, 64, 14),  // MNIST: ragged rows (14 % 8 != 0)
        (100, 64, 14), // ragged channels too
        (3, 128, 32),  // thin input without bitplane expansion
    ] {
        let p = conv_plan(c_in, c_out, s);
        let cycles = p.cycles(&hw, 1);
        let util = p.utilization(&hw, 1);
        let gops = util * hw.peak_gops();
        println!(
            "  {c_in:>6} {c_out:>6} {:>6} {cycles:>12} {gops:>10.0} {:>8.1}",
            format!("{s}x{s}"),
            util * 100.0
        );
    }
    println!(
        "  (geometry that divides the 32-block/8-row fabric runs at ~full \
         utilization — the paper's claim; ragged edges show the cost of padding.)"
    );

    if quick {
        report.write(REPORT_PATH);
        dse_best_config(&mut report, true);
        report.write(REPORT2_PATH);
        println!("\n--quick: skipping artifact-dependent and serving sections");
        return;
    }

    section("end-to-end effective throughput per model (chip cycles)");
    for (name, path) in [
        ("tiny", "artifacts/tiny_t4.vsaw"),
        ("mnist", "artifacts/mnist_t8.vsaw"),
        ("cifar10", "artifacts/cifar10_t8.vsaw"),
    ] {
        let Ok(net) = Network::from_vsaw_file(path) else {
            eprintln!("  {name}: run `make artifacts`");
            continue;
        };
        let img = &synth::for_model(name, 3, 0, 1)[0].image;
        let r = Chip::new(hw.clone(), SimMode::Fast).run(&net.model, img);
        println!(
            "  {name:<8} {:>10} cycles  {:>8.1} us  {:>6.0} GOPS eff ({:.0}% of peak)",
            r.cycles,
            r.latency_us,
            r.gops,
            r.gops / hw.peak_gops() * 100.0
        );
    }

    section("vectorwise vs elementwise (SpinalFlow-style) on mnist");
    {
        // Artifact weights if present, synthesized otherwise — the
        // comparison is structural, not accuracy-dependent.
        let model = Network::from_vsaw_file("artifacts/mnist_t8.vsaw")
            .map(|n| n.model)
            .unwrap_or_else(|_| {
                DeployedModel::synthesize(&models::by_name("mnist", 8).unwrap(), 7)
            });
        let img = &synth::mnist_like(3, 0, 1)[0].image;
        let vsa_r = Chip::new(hw.clone(), SimMode::Fast).run(&model, img);
        let sf = spinalflow::run(&SpinalFlowConfig::default(), &model, img);
        println!(
            "  VSA:        {:>10} cycles @500MHz = {:>9.1} us  ({:.0} GOPS eff)",
            vsa_r.cycles, vsa_r.latency_us, vsa_r.gops
        );
        println!(
            "  SpinalFlow: {:>10} cycles @200MHz = {:>9.1} us  ({:.1} GOPS eff, \
             {} spikes processed)",
            sf.cycles, sf.latency_us, sf.effective_gops, sf.total_spikes
        );
        println!(
            "  speedup {:.1}x — the paper's elementwise-vs-vectorwise ordering",
            sf.latency_us / vsa_r.latency_us
        );
    }

    section("serving throughput vs batch size (coordinator, golden engine)");
    {
        let spec = models::by_name("tiny", 4).unwrap();
        let model = DeployedModel::synthesize(&spec, 7);
        println!("  {:>6} {:>12} {:>10}", "batch", "req/s", "p50 ms");
        let mut best_rps = 0.0f64;
        for batch in [1usize, 4, 8, 16] {
            let (reg, m) = ModelRegistry::single(model.clone());
            let regc = Arc::clone(&reg);
            let coord = Coordinator::start(
                CoordinatorConfig {
                    workers: 2,
                    max_batch: batch,
                    max_wait: Duration::from_micros(500),
                    queue_depth: 256,
                    ..CoordinatorConfig::default()
                },
                reg,
                move |_| {
                    Box::new(GoldenEngine::new(Arc::clone(&regc), batch))
                        as Box<dyn InferenceEngine>
                },
            );
            let samples = synth::tiny_like(5, 0, 256);
            let rxs: Vec<_> = samples
                .iter()
                .map(|s| coord.submit(m, s.image.clone()).unwrap())
                .collect();
            for rx in rxs {
                rx.recv().unwrap();
            }
            let stats = coord.shutdown();
            best_rps = best_rps.max(stats.throughput_rps);
            println!(
                "  {batch:>6} {:>12.0} {:>10.3}",
                stats.throughput_rps, stats.latency_ms_p50
            );
        }
        report.throughput(
            "coordinator-golden",
            "tiny",
            best_rps,
            "best req/s across batch sweep, 2 workers",
        );
    }

    report.write(REPORT_PATH);
    dse_best_config(&mut report, false);
    report.write(REPORT2_PATH);
}

//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the subset of `anyhow` the workspace uses:
//!
//! * [`Error`] — a single-string error value with a context chain folded
//!   into the message;
//! * [`Result<T>`] with the `Error` default;
//! * a blanket `From<E: std::error::Error>` so `?` converts any std
//!   error (mirroring real `anyhow`, [`Error`] itself deliberately does
//!   NOT implement `std::error::Error`, which keeps the blanket impl
//!   coherent);
//! * the [`Context`] extension trait on `Result` and `Option`;
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros (format-string and
//!   single-expression forms).
//!
//! Swap back to the real crate by deleting `vendor/anyhow` and pointing
//! the workspace dependency at crates.io.

use std::fmt;

/// A boxed-free, single-message error with its context chain pre-folded.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string() }
    }

    fn wrap(self, context: impl fmt::Display) -> Self {
        Self { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` on real anyhow prints the whole chain; ours is already
        // folded into one message, so both forms print the same thing.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `Result` defaulting to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attaching extension for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message to the error/none case.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $($arg:tt)*)?) => {
        $crate::Error::msg(format!($fmt $(, $($arg)*)?))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an error when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_chains_messages() {
        let e: Result<()> = std::result::Result::<(), _>::Err(io_err()).context("reading x");
        assert_eq!(e.unwrap_err().to_string(), "reading x: gone");
        let n: Result<u8> = None.with_context(|| format!("missing {}", "y"));
        assert_eq!(n.unwrap_err().to_string(), "missing y");
    }

    #[test]
    fn macros_build_errors() {
        let value = 3;
        let e = anyhow!("bad value {value} ({})", "extra");
        assert_eq!(e.to_string(), "bad value 3 (extra)");
        let from_string = anyhow!(String::from("plain"));
        assert_eq!(from_string.to_string(), "plain");

        fn guarded(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            ensure!(x < 100);
            if x == 13 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(guarded(5).unwrap(), 5);
        assert!(guarded(-1).unwrap_err().to_string().contains("positive"));
        assert!(guarded(200).unwrap_err().to_string().contains("condition failed"));
        assert!(guarded(13).unwrap_err().to_string().contains("unlucky"));
    }

    #[test]
    fn alternate_display_matches_plain() {
        let e = anyhow!("top").wrap("ctx");
        assert_eq!(format!("{e}"), format!("{e:#}"));
        assert_eq!(format!("{e:?}"), "ctx: top");
    }
}

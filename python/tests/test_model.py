"""L2 correctness: model shapes, IF-BN identity, deploy/quantize, AOT IO."""

import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import datasets, params_io
from compile.kernels import ref
from compile.model import (
    SPECS,
    cifar10_spec,
    deploy,
    forward_deployed,
    forward_deployed_batched,
    forward_train,
    forward_train_ann,
    init_params,
    mnist_spec,
    tiny_spec,
)

HYPO = dict(max_examples=15, deadline=None)


# --------------------------------------------------------------------------
# Table I topologies
# --------------------------------------------------------------------------


def test_mnist_spec_matches_table1():
    spec = mnist_spec()
    kinds = [ly.kind for ly in spec.layers]
    assert kinds == ["enc_conv", "maxpool", "conv", "maxpool", "fc", "readout"]
    assert [ly.c_out for ly in spec.layers if ly.c_out] == [64, 64, 128, 10]
    # fc sees 64 x 7 x 7 = 3136 inputs
    assert spec.feature_shapes()[4] == (64, 7, 7)


def test_cifar10_spec_matches_table1():
    spec = cifar10_spec()
    convs = [ly.c_out for ly in spec.layers if ly.kind in ("enc_conv", "conv")]
    assert convs == [128, 128, 128, 192, 192, 192, 192, 256, 256, 256, 256]
    pools = sum(ly.kind == "maxpool" for ly in spec.layers)
    assert pools == 3
    assert [ly.c_out for ly in spec.layers if ly.kind in ("fc", "readout")] == [256, 10]
    # fc sees 256 x 4 x 4 = 4096 inputs; readout sees the 256 fc neurons
    assert spec.feature_shapes()[-1] == (256, 1, 1)
    assert spec.feature_shapes()[-2] == (256, 4, 4)


def test_feature_shapes_mnist():
    spec = mnist_spec()
    shapes = spec.feature_shapes()
    assert shapes[0] == (1, 28, 28)
    assert shapes[1] == (64, 28, 28)
    assert shapes[2] == (64, 14, 14)


# --------------------------------------------------------------------------
# IF-BN identity (paper Eq. (3) == Eq. (4))
# --------------------------------------------------------------------------


@settings(**HYPO)
@given(
    t=st.integers(1, 10),
    seed=st.integers(0, 2**31),
)
def test_if_bn_folding_identity(t, seed):
    """Accumulated BN outputs cross Vth  <=>  folded IF-BN neuron fires.

    This is the paper's Eq. (3) <-> Eq. (4) rearrangement, checked on the
    *unquantized* float formulation for the first firing time.
    """
    rng = np.random.default_rng(seed)
    c = 8
    x = rng.normal(0, 3, (t, c)).astype(np.float64)
    gamma = rng.uniform(0.2, 2.0, c)
    beta = rng.normal(0, 1, c)
    mu = rng.normal(0, 1, c)
    var = rng.uniform(0.1, 4.0, c)
    v_th = 1.0
    eps = 0.0

    sigma = np.sqrt(var + eps)
    # Eq. (3): accumulate BN(x) and compare against Vth.
    bn = gamma * (x - mu) / sigma + beta
    lhs_fires = bn.cumsum(axis=0) >= v_th
    # Eq. (4): accumulate (x - bias) and compare against theta.
    bias = mu - sigma / gamma * beta
    theta = sigma / gamma * v_th
    rhs_fires = (x - bias).cumsum(axis=0) >= theta

    # Identity holds for every prefix sum (before any reset).
    np.testing.assert_array_equal(lhs_fires, rhs_fires)


def test_quantize_if_bn_integer_grid():
    gamma = jnp.array([0.5, 1.0, 2.0])
    beta = jnp.array([0.1, -0.2, 0.3])
    mu = jnp.array([1.0, 0.0, -1.0])
    var = jnp.array([1.0, 4.0, 0.25])
    b, th = ref.quantize_if_bn(gamma, beta, mu, var, 1.0)
    # Quantized values are integers and theta is strictly positive.
    np.testing.assert_array_equal(np.asarray(b), np.round(np.asarray(b)))
    np.testing.assert_array_equal(np.asarray(th), np.round(np.asarray(th)))
    assert (np.asarray(th) >= 1).all()


# --------------------------------------------------------------------------
# Deployed forward
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_deployed():
    spec = tiny_spec()
    params = init_params(jax.random.PRNGKey(0), spec)
    return spec, deploy(params, spec)


def test_deployed_logits_integer_valued(tiny_deployed):
    spec, d = tiny_deployed
    imgs, _ = datasets.tiny_like(3, 0, 2)
    logits = forward_deployed(d, spec, jnp.asarray(imgs[0], jnp.float32))
    arr = np.asarray(logits)
    assert arr.shape == (10,)
    np.testing.assert_array_equal(arr, np.round(arr))


def test_deployed_pallas_equals_ref_path(tiny_deployed):
    spec, d = tiny_deployed
    imgs, _ = datasets.tiny_like(4, 100, 2)
    x = jnp.asarray(imgs, jnp.float32)
    a = forward_deployed_batched(d, spec, x, use_pallas=True)
    b = forward_deployed_batched(d, spec, x, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_deployed_deterministic(tiny_deployed):
    spec, d = tiny_deployed
    imgs, _ = datasets.tiny_like(5, 0, 1)
    x = jnp.asarray(imgs[0], jnp.float32)
    a = forward_deployed(d, spec, x)
    b = forward_deployed(d, spec, x)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Training view
# --------------------------------------------------------------------------


def test_forward_train_shapes_and_grads():
    spec = tiny_spec()
    params = init_params(jax.random.PRNGKey(1), spec)
    imgs, labels = datasets.tiny_like(1, 0, 4)
    x = jnp.asarray(imgs, jnp.float32) / 255.0

    def loss(p):
        logits, _ = forward_train(p, spec, x)
        assert logits.shape == (4, 10)
        onehot = jax.nn.one_hot(jnp.asarray(labels), 10)
        return ((jax.nn.log_softmax(logits) * onehot).sum(-1)).mean() * -1

    grads = jax.grad(loss)(params)
    # Surrogate gradients reach the *encoding layer* weights (STBP through
    # all layers and time steps).
    g0 = np.asarray(grads[0]["w"])
    assert np.isfinite(g0).all()
    assert np.abs(g0).sum() > 0


def test_forward_train_ann_shapes():
    spec = tiny_spec()
    params = init_params(jax.random.PRNGKey(2), spec)
    imgs, _ = datasets.tiny_like(2, 0, 3)
    logits = forward_train_ann(params, spec, jnp.asarray(imgs, jnp.float32) / 255.0)
    assert logits.shape == (3, 10)
    assert np.isfinite(np.asarray(logits)).all()


def test_train_smoke_loss_decreases():
    from compile.train import train

    spec = tiny_spec(num_steps=2)
    log = []
    train(spec, steps=30, batch=16, lr=2e-3, log=log, log_every=29)
    assert log[-1]["loss"] < log[0]["loss"]


# --------------------------------------------------------------------------
# VSAW round-trip
# --------------------------------------------------------------------------


def test_vsaw_roundtrip(tiny_deployed):
    spec, d = tiny_deployed
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "m.vsaw")
        params_io.save_deployed(path, d, spec)
        name, t, c, s, layers = params_io.load_deployed(path)
        assert (name, t, c, s) == (spec.name, spec.num_steps, 1, 12)
        assert len(layers) == len(spec.layers)
        for ly, orig, spec_ly in zip(layers, d, spec.layers):
            assert ly["kind"] == spec_ly.kind
            if "w" in orig:
                np.testing.assert_array_equal(ly["w"], np.asarray(orig["w"]))
            if "bias" in orig:
                np.testing.assert_array_equal(ly["bias"], np.asarray(orig["bias"]))
                np.testing.assert_array_equal(ly["theta"], np.asarray(orig["theta"]))


def test_vsaw_reload_same_logits(tiny_deployed):
    spec, d = tiny_deployed
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "m.vsaw")
        params_io.save_deployed(path, d, spec)
        _, _, _, _, layers = params_io.load_deployed(path)
        d2 = [
            {k: jnp.asarray(v) for k, v in ly.items() if k != "kind"} for ly in layers
        ]
        imgs, _ = datasets.tiny_like(9, 0, 2)
        x = jnp.asarray(imgs, jnp.float32)
        a = forward_deployed_batched(d, spec, x, use_pallas=False)
        b = forward_deployed_batched(d2, spec, x, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Synthetic dataset invariants
# --------------------------------------------------------------------------


def test_dataset_deterministic():
    a, la = datasets.mnist_like(42, 0, 4)
    b, lb = datasets.mnist_like(42, 0, 4)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)


def test_dataset_labels_balanced():
    _, labels = datasets.tiny_like(1, 0, 50)
    counts = np.bincount(labels, minlength=10)
    assert (counts == 5).all()


def test_dataset_pixel_range():
    imgs, _ = datasets.cifar_like(3, 0, 2)
    assert imgs.dtype == np.uint8
    assert imgs.shape == (2, 3, 32, 32)


def test_splitmix64_known_values():
    # Cross-language anchor: rust/src/util/rng.rs asserts the same outputs.
    state, z1 = datasets.splitmix64(0)
    _, z2 = datasets.splitmix64(state)
    assert z1 == 0xE220A8397B1DCDAF
    assert z2 == 0x6E789E6AA1B965F4

"""AOT pipeline: HLO text must be loadable by the (old) XLA text parser
and must carry the baked-in weights.

Regression guards for the two interchange bugs found during bring-up:
* default printing elides large constants as ``constant({...})`` — the
  rust-side parser silently refills them with ZEROS;
* jax's metadata attributes (``source_end_line`` ...) are rejected by the
  xla_extension 0.5.1 text parser.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, datasets
from compile.model import SPECS, deploy, forward_deployed, init_params


@pytest.fixture(scope="module")
def tiny_hlo():
    spec = SPECS["tiny"]()
    deployed = deploy(init_params(jax.random.PRNGKey(aot.SEED), spec), spec)
    return aot.lower_model(spec, deployed, batch=1, use_pallas=False), spec, deployed


def test_hlo_has_no_elided_constants(tiny_hlo):
    text, _, _ = tiny_hlo
    assert "constant({...})" not in text, "large constants were elided"


def test_hlo_has_no_metadata_attributes(tiny_hlo):
    text, _, _ = tiny_hlo
    assert "source_end_line" not in text
    assert "metadata=" not in text


def test_hlo_entry_signature(tiny_hlo):
    text, spec, _ = tiny_hlo
    s = spec.in_size
    # parameter (1, C, S, S) -> tuple((1, 10))
    assert re.search(rf"f32\[1,{spec.in_channels},{s},{s}\]", text)
    assert re.search(r"\(f32\[1,10\]", text)


def test_hlo_contains_weight_values(tiny_hlo):
    text, spec, deployed = tiny_hlo
    # the fc weight matrix must appear as a materialized constant (XLA may
    # fold the transpose, so accept either orientation)
    n_in = 32 * (spec.in_size // 4) ** 2
    assert re.search(
        rf"f32\[(64,{n_in}|{n_in},64)\]\S* constant\(\{{", text
    ), "fc weights not materialized in the HLO text"
    # and it must carry actual +-1 values
    assert re.search(r"constant\(\{ \{ -?1, ", text)


def test_selfcheck_logits_are_reproducible():
    """The logits aot.py writes must match a fresh recompute — guards
    against stale artifacts and nondeterminism in deploy()."""
    spec = SPECS["tiny"]()
    deployed = aot.build_params(spec, None)
    imgs, _ = datasets.FOR_SPEC["tiny"](aot.SELFCHECK_DATA_SEED, 0, 2)
    a = [
        np.asarray(
            forward_deployed(deployed, spec, jnp.asarray(i, jnp.float32), use_pallas=False)
        )
        for i in imgs
    ]
    b = [
        np.asarray(
            forward_deployed(deployed, spec, jnp.asarray(i, jnp.float32), use_pallas=False)
        )
        for i in imgs
    ]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)

"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/values; equality is exact (integer-valued f32
arithmetic, see ref.py docstring).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.binary_conv import binary_conv2d, binary_conv2d_batched
from compile.kernels.binary_matmul import binary_matmul
from compile.kernels.encoding import encoding_conv2d
from compile.kernels.if_neuron import if_dynamics, if_dynamics_flat

HYPO = dict(max_examples=20, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


def rand_spikes(rng, shape):
    return rng.integers(0, 2, shape).astype(np.float32)


def rand_weights(rng, shape):
    return rng.choice([-1.0, 1.0], shape).astype(np.float32)


# --------------------------------------------------------------------------
# binary_conv
# --------------------------------------------------------------------------


@settings(**HYPO)
@given(
    c_in=st.integers(1, 8),
    c_out=st.sampled_from([1, 3, 16, 32, 48]),
    size=st.integers(4, 14),
    k=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2**31),
)
def test_binary_conv_matches_ref(c_in, c_out, size, k, seed):
    rng = _rng(seed)
    x = rand_spikes(rng, (c_in, size, size))
    w = rand_weights(rng, (c_out, c_in, k, k))
    got = binary_conv2d(jnp.array(x), jnp.array(w))
    want = ref.conv2d_binary(jnp.array(x), jnp.array(w))
    assert got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_binary_conv_batched_matches_ref():
    rng = _rng(7)
    x = rand_spikes(rng, (4, 16, 10, 10))
    w = rand_weights(rng, (32, 16, 3, 3))
    got = binary_conv2d_batched(jnp.array(x), jnp.array(w))
    want = ref.conv2d_binary_batched(jnp.array(x), jnp.array(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_binary_conv_output_is_integer_valued():
    rng = _rng(3)
    x = rand_spikes(rng, (8, 9, 9))
    w = rand_weights(rng, (24, 8, 3, 3))
    out = np.asarray(binary_conv2d(jnp.array(x), jnp.array(w)))
    np.testing.assert_array_equal(out, np.round(out))
    assert np.abs(out).max() <= 8 * 9  # |sum| <= C_in * K * K


def test_binary_conv_all_positive_weights_counts_spikes():
    # With w == +1 everywhere, conv == local spike count (popcount).
    rng = _rng(11)
    x = rand_spikes(rng, (2, 6, 6))
    w = np.ones((1, 2, 3, 3), np.float32)
    out = np.asarray(binary_conv2d(jnp.array(x), jnp.array(w)))
    xp = np.pad(x, ((0, 0), (1, 1), (1, 1)))
    manual = np.zeros((6, 6), np.float32)
    for yy in range(6):
        for xx in range(6):
            manual[yy, xx] = xp[:, yy : yy + 3, xx : xx + 3].sum()
    np.testing.assert_array_equal(out[0], manual)


# --------------------------------------------------------------------------
# if_neuron
# --------------------------------------------------------------------------


@settings(**HYPO)
@given(
    t=st.integers(1, 10),
    c=st.sampled_from([1, 2, 32, 48]),
    size=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_if_dynamics_matches_ref(t, c, size, seed):
    rng = _rng(seed)
    psums = rng.integers(-30, 30, (t, c, size, size)).astype(np.float32)
    bias = rng.integers(-10, 10, c).astype(np.float32)
    theta = rng.integers(1, 15, c).astype(np.float32)
    s1, v1 = if_dynamics(jnp.array(psums), jnp.array(bias), jnp.array(theta))
    s2, v2 = ref.if_dynamics(jnp.array(psums), jnp.array(bias), jnp.array(theta))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_if_dynamics_spikes_are_binary():
    rng = _rng(5)
    psums = rng.integers(-50, 50, (6, 16, 4, 4)).astype(np.float32)
    s, _ = if_dynamics(
        jnp.array(psums), jnp.zeros(16), jnp.full(16, 5.0)
    )
    assert set(np.unique(np.asarray(s))) <= {0.0, 1.0}


def test_if_hard_reset_membrane_below_threshold():
    # After any fire, the residual membrane is exactly zero (hard reset).
    psums = np.full((1, 1, 1, 1), 100.0, np.float32)
    s, v = if_dynamics(jnp.array(psums), jnp.zeros(1), jnp.ones(1))
    assert np.asarray(s)[0, 0, 0, 0] == 1.0
    assert np.asarray(v)[0, 0, 0] == 0.0


def test_if_subthreshold_accumulates():
    # theta=10, psum=3 each step: fires at t=3 (V=12 >= 10), resets.
    psums = np.full((5, 1, 1, 1), 3.0, np.float32)
    s, v = if_dynamics(jnp.array(psums), jnp.zeros(1), jnp.full(1, 10.0))
    np.testing.assert_array_equal(
        np.asarray(s).ravel(), [0.0, 0.0, 0.0, 1.0, 0.0]
    )
    assert np.asarray(v).item() == 3.0


def test_if_flat_matches_4d():
    rng = _rng(9)
    psums = rng.integers(-10, 10, (4, 24)).astype(np.float32)
    bias = rng.integers(-3, 3, 24).astype(np.float32)
    theta = rng.integers(1, 8, 24).astype(np.float32)
    s1, v1 = if_dynamics_flat(jnp.array(psums), jnp.array(bias), jnp.array(theta))
    s2, v2 = ref.if_dynamics(jnp.array(psums), jnp.array(bias), jnp.array(theta))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


# --------------------------------------------------------------------------
# encoding (bitplane) conv
# --------------------------------------------------------------------------


@settings(**HYPO)
@given(
    c_in=st.integers(1, 3),
    c_out=st.sampled_from([1, 16, 32]),
    size=st.integers(4, 12),
    seed=st.integers(0, 2**31),
)
def test_encoding_conv_matches_direct_conv(c_in, c_out, size, seed):
    rng = _rng(seed)
    img = rng.integers(0, 256, (c_in, size, size)).astype(np.float32)
    w = rand_weights(rng, (c_out, c_in, 3, 3))
    got = encoding_conv2d(jnp.array(img), jnp.array(w))
    want = ref.conv2d_binary(jnp.array(img), jnp.array(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_encoding_bitplane_ref_identity():
    rng = _rng(17)
    img = rng.integers(0, 256, (3, 8, 8)).astype(np.float32)
    w = rand_weights(rng, (16, 3, 3, 3))
    bias = rng.integers(-100, 100, 16).astype(np.float32)
    theta = rng.integers(1, 200, 16).astype(np.float32)
    s1, v1 = ref.encoding_layer(jnp.array(img), jnp.array(w), jnp.array(bias), jnp.array(theta), 6)
    s2, v2 = ref.encoding_layer_bitplanes(
        jnp.array(img), jnp.array(w), jnp.array(bias), jnp.array(theta), 6
    )
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))


def test_encoding_conv_rejects_nothing_on_zero_image():
    w = np.ones((4, 1, 3, 3), np.float32)
    out = encoding_conv2d(jnp.zeros((1, 5, 5)), jnp.array(w))
    np.testing.assert_array_equal(np.asarray(out), 0.0)


# --------------------------------------------------------------------------
# binary_matmul
# --------------------------------------------------------------------------


@settings(**HYPO)
@given(
    t=st.integers(1, 8),
    n_in=st.integers(1, 96),
    n_out=st.sampled_from([1, 10, 64, 128, 130]),
    seed=st.integers(0, 2**31),
)
def test_binary_matmul_matches_ref(t, n_in, n_out, seed):
    rng = _rng(seed)
    s = rand_spikes(rng, (t, n_in))
    w = rand_weights(rng, (n_out, n_in))
    got = binary_matmul(jnp.array(s), jnp.array(w))
    np.testing.assert_array_equal(np.asarray(got), s @ w.T)


# --------------------------------------------------------------------------
# maxpool / readout oracles (sanity for the contract itself)
# --------------------------------------------------------------------------


def test_maxpool_is_or_on_spikes():
    x = np.zeros((2, 1, 4, 4), np.float32)
    x[0, 0, 0, 1] = 1.0  # only one spike in the top-left 2x2 window
    out = np.asarray(ref.maxpool2(jnp.array(x)))
    assert out.shape == (2, 1, 2, 2)
    assert out[0, 0, 0, 0] == 1.0 and out[1].sum() == 0.0


def test_readout_accumulates_membrane():
    rng = _rng(23)
    s = rand_spikes(rng, (5, 12))
    w = rand_weights(rng, (10, 12))
    got = np.asarray(ref.readout_layer(jnp.array(s), jnp.array(w)))
    np.testing.assert_array_equal(got, (s @ w.T).sum(0))

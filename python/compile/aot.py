"""AOT pipeline: lower the deployed SNN graphs to HLO text for rust.

Emits HLO **text** (NOT ``lowered.compile()`` / ``.serialize()``): jax>=0.5
writes HloModuleProto with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects; the HLO text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

For every exported model this writes:

* ``artifacts/<name>_t<T>_b<B>.hlo.txt``  — the lowered inference module
  (pallas kernels included, interpret-mode, so it runs on the CPU PJRT
  client the rust runtime creates);
* ``artifacts/<name>_t<T>.vsaw``          — the identical weights in VSAW
  format for the rust golden model / simulator;
* ``artifacts/manifest.json``             — registry the rust runtime loads.

Weights are deterministic (seeded init + deploy) unless a trained ``.vsaw``
checkpoint is supplied via ``--weights`` for that model.

Usage:  python -m compile.aot --out ../artifacts  (a file path ending in
``.hlo.txt`` is also accepted for Makefile compatibility: its directory is
used and a copy of the mnist module is placed at the given name).
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets, params_io
from .model import SPECS, ModelSpec, deploy, forward_deployed, init_params

SEED = 1234
SELFCHECK_SAMPLES = 4
SELFCHECK_DATA_SEED = 777


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser).

    Printed with ``print_large_constants=True``: the default printer elides
    big literals as ``constant({...})``, which the rust-side HLO text
    parser would silently refill with zeros — the baked-in weights MUST be
    materialized in the text.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax's printer emits source_end_line/... metadata attributes that the
    # xla_extension 0.5.1 text parser rejects; drop metadata entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_model(
    spec: ModelSpec,
    deployed: list[dict[str, Any]],
    batch: int,
    use_pallas: bool,
) -> str:
    """Lower batched deployed inference to HLO text.

    The weights are baked in as constants (the chip analogue: weights
    resident in the weight SRAM); the only runtime parameter is the u8
    image batch, shaped (B, C, H, W) float32.
    """

    def fn(images):
        return (
            jax.vmap(
                lambda img: forward_deployed(deployed, spec, img, use_pallas=use_pallas)
            )(images),
        )

    shape = jax.ShapeDtypeStruct(
        (batch, spec.in_channels, spec.in_size, spec.in_size), jnp.float32
    )
    return to_hlo_text(jax.jit(fn).lower(shape))


def build_params(spec: ModelSpec, weights_path: str | None):
    """Deterministic deploy()-ed params, or a trained checkpoint if given."""
    if weights_path and os.path.exists(weights_path):
        name, t, c, s, layers = params_io.load_deployed(weights_path)
        assert (t, c, s) == (spec.num_steps, spec.in_channels, spec.in_size), (
            f"checkpoint {weights_path} geometry mismatch for {spec.name}"
        )
        dep = []
        for ly in layers:
            d = {k: jnp.asarray(v) for k, v in ly.items() if k != "kind"}
            dep.append(d)
        return dep
    params = init_params(jax.random.PRNGKey(SEED), spec)
    return deploy(params, spec)


def export_model(
    outdir: str,
    spec: ModelSpec,
    batches: tuple[int, ...],
    use_pallas: bool,
    weights_path: str | None = None,
) -> list[dict[str, Any]]:
    """Export one model at several batch sizes; returns manifest entries."""
    deployed = build_params(spec, weights_path)
    wfile = f"{spec.name}_t{spec.num_steps}.vsaw"
    params_io.save_deployed(os.path.join(outdir, wfile), deployed, spec)

    # Cross-language self-check: expected logits for a few deterministic
    # synthetic samples.  rust/tests/golden_vs_jax.rs regenerates the same
    # images (bit-identical splitmix64 generator) and asserts its golden
    # model produces these exact integers.
    gen = datasets.FOR_SPEC[spec.name]
    imgs, labels = gen(SELFCHECK_DATA_SEED, 0, SELFCHECK_SAMPLES)
    logits = [
        np.asarray(
            forward_deployed(deployed, spec, jnp.asarray(img, jnp.float32),
                             use_pallas=False)
        ).astype(int).tolist()
        for img in imgs
    ]
    check = dict(
        data_seed=SELFCHECK_DATA_SEED, start=0, count=SELFCHECK_SAMPLES,
        labels=labels.tolist(), logits=logits,
    )
    cfile = f"{spec.name}_t{spec.num_steps}_selfcheck.json"
    with open(os.path.join(outdir, cfile), "w") as f:
        json.dump(check, f)

    entries = []
    for b in batches:
        hlo = lower_model(spec, deployed, b, use_pallas)
        hfile = f"{spec.name}_t{spec.num_steps}_b{b}.hlo.txt"
        with open(os.path.join(outdir, hfile), "w") as f:
            f.write(hlo)
        entries.append(
            dict(
                name=spec.name,
                hlo=hfile,
                weights=wfile,
                batch=b,
                num_steps=spec.num_steps,
                in_channels=spec.in_channels,
                in_size=spec.in_size,
                num_classes=10,
                pallas=use_pallas,
            )
        )
        print(f"wrote {hfile} ({len(hlo)} chars)", flush=True)
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models",
        default="tiny,mnist,cifar10",
        help="comma-separated subset of " + ",".join(sorted(SPECS)),
    )
    ap.add_argument("--weights", default=None, help="trained .vsaw for the model")
    args = ap.parse_args()

    out = args.out
    legacy_target = None
    if out.endswith(".hlo.txt"):  # Makefile `--out ../artifacts/model.hlo.txt`
        legacy_target = out
        out = os.path.dirname(out) or "."
    os.makedirs(out, exist_ok=True)

    manifest: list[dict[str, Any]] = []
    wanted = args.models.split(",")
    if "tiny" in wanted:
        manifest += export_model(
            out, SPECS["tiny"](), batches=(1, 8), use_pallas=True,
            weights_path=args.weights,
        )
    if "mnist" in wanted:
        manifest += export_model(
            out, SPECS["mnist"](), batches=(1, 8), use_pallas=True,
            weights_path=args.weights,
        )
    if "cifar10" in wanted:
        # The full CIFAR-10 net traces 11 pallas conv layers x T=8; use the
        # (bit-identical) jnp path to keep artifact builds fast.  The pallas
        # datapath is exercised by tiny/mnist and the pytest suite.
        manifest += export_model(
            out, SPECS["cifar10"](), batches=(1,), use_pallas=False,
            weights_path=args.weights,
        )

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest)} entries)")

    if legacy_target:
        src = next(e["hlo"] for e in manifest if e["batch"] == 1)
        with open(os.path.join(out, src)) as fi, open(legacy_target, "w") as fo:
            fo.write(fi.read())
        print(f"wrote {legacy_target}")


if __name__ == "__main__":
    main()

"""L2: the binary-weight spiking models of VSA (paper Table I).

Two views of the same network:

* **Training view** (`forward_train`) — float arithmetic, latent real
  weights binarized with a straight-through estimator, standard BatchNorm
  (shared statistics across time steps, as in paper Eq. (3)), IF neurons
  with a rectangular surrogate gradient.  Differentiable end-to-end: this
  is the STBP graph `compile/train.py` optimizes.

* **Deployed view** (`forward_deployed`) — the integer-exact inference
  graph the hardware runs: binary +-1 weights, BN folded into IF-BN
  (bias, theta) quantized on the ``FIXED_POINT`` grid (paper Eq. (4)),
  multi-bit u8 input into the encoding layer.  Calls the Pallas kernels
  (L1) so the whole thing lowers into one HLO module for the rust runtime.
  Bit-identical to the rust golden model and the cycle-accurate simulator.

Network structures (paper Table I)
----------------------------------
MNIST    : 64Conv(encoding)-MP2-64Conv-MP2-128fc-10fc
CIFAR-10 : 128Conv(encoding)-128Conv-128Conv-MP2-192Conv-192Conv-192Conv-
           192Conv-MP2-256Conv-256Conv-256Conv-256Conv-MP2-256fc-10fc
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.binary_conv import binary_conv2d_batched
from .kernels.binary_matmul import binary_matmul
from .kernels.encoding import encoding_conv2d
from .kernels.if_neuron import if_dynamics, if_dynamics_flat

FIXED_POINT = ref.FIXED_POINT
DEFAULT_V_TH = 1.0
BN_EPS = 1e-5


# --------------------------------------------------------------------------
# Architecture description
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of a Table-I network.

    kind: 'enc_conv' | 'conv' | 'maxpool' | 'fc' | 'readout'.
    """

    kind: str
    c_out: int = 0
    ksize: int = 3


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """A full network: input geometry + layer stack + time steps."""

    name: str
    in_channels: int
    in_size: int
    layers: tuple[LayerSpec, ...]
    num_steps: int = 8

    def feature_shapes(self) -> list[tuple[int, int, int]]:
        """(C, H, W) entering each layer (flattened dims for fc layers)."""
        shapes = []
        c, s = self.in_channels, self.in_size
        for ly in self.layers:
            shapes.append((c, s, s))
            if ly.kind in ("enc_conv", "conv"):
                c = ly.c_out
            elif ly.kind == "maxpool":
                s //= 2
            elif ly.kind in ("fc", "readout"):
                c, s = ly.c_out, 1
        return shapes


def mnist_spec(num_steps: int = 8) -> ModelSpec:
    """MNIST network from Table I."""
    return ModelSpec(
        name="mnist",
        in_channels=1,
        in_size=28,
        layers=(
            LayerSpec("enc_conv", 64),
            LayerSpec("maxpool"),
            LayerSpec("conv", 64),
            LayerSpec("maxpool"),
            LayerSpec("fc", 128),
            LayerSpec("readout", 10),
        ),
        num_steps=num_steps,
    )


def cifar10_spec(num_steps: int = 8) -> ModelSpec:
    """CIFAR-10 network from Table I (11 weight layers + 3 pools)."""
    convs = [128, 128, 128, "MP", 192, 192, 192, 192, "MP", 256, 256, 256, 256, "MP"]
    layers: list[LayerSpec] = []
    first = True
    for c in convs:
        if c == "MP":
            layers.append(LayerSpec("maxpool"))
        elif first:
            layers.append(LayerSpec("enc_conv", int(c)))
            first = False
        else:
            layers.append(LayerSpec("conv", int(c)))
    layers += [LayerSpec("fc", 256), LayerSpec("readout", 10)]
    return ModelSpec(
        name="cifar10", in_channels=3, in_size=32, layers=tuple(layers),
        num_steps=num_steps,
    )


def tiny_spec(num_steps: int = 4) -> ModelSpec:
    """Small net for fast tests and the e2e training example (~100k params)."""
    return ModelSpec(
        name="tiny",
        in_channels=1,
        in_size=12,
        layers=(
            LayerSpec("enc_conv", 16),
            LayerSpec("maxpool"),
            LayerSpec("conv", 32),
            LayerSpec("maxpool"),
            LayerSpec("fc", 64),
            LayerSpec("readout", 10),
        ),
        num_steps=num_steps,
    )


SPECS = {"mnist": mnist_spec, "cifar10": cifar10_spec, "tiny": tiny_spec}


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def init_params(key: jax.Array, spec: ModelSpec) -> list[dict[str, Any]]:
    """Initialize latent float weights + BN state for every weight layer.

    Returns a list parallel to ``spec.layers``; pool layers get ``{}``.
    """
    params: list[dict[str, Any]] = []
    shapes = spec.feature_shapes()
    for ly, (c_in, h, w) in zip(spec.layers, shapes):
        if ly.kind in ("enc_conv", "conv"):
            key, sub = jax.random.split(key)
            fan_in = c_in * ly.ksize * ly.ksize
            params.append(
                dict(
                    w=jax.random.normal(sub, (ly.c_out, c_in, ly.ksize, ly.ksize))
                    / jnp.sqrt(fan_in),
                    gamma=jnp.ones(ly.c_out),
                    beta=jnp.zeros(ly.c_out),
                    mu=jnp.zeros(ly.c_out),
                    var=jnp.ones(ly.c_out),
                    v_th=DEFAULT_V_TH,
                )
            )
        elif ly.kind == "fc":
            key, sub = jax.random.split(key)
            n_in = c_in * h * w
            params.append(
                dict(
                    w=jax.random.normal(sub, (ly.c_out, n_in)) / jnp.sqrt(n_in),
                    gamma=jnp.ones(ly.c_out),
                    beta=jnp.zeros(ly.c_out),
                    mu=jnp.zeros(ly.c_out),
                    var=jnp.ones(ly.c_out),
                    v_th=DEFAULT_V_TH,
                )
            )
        elif ly.kind == "readout":
            key, sub = jax.random.split(key)
            n_in = c_in * h * w
            params.append(
                dict(w=jax.random.normal(sub, (ly.c_out, n_in)) / jnp.sqrt(n_in))
            )
        else:
            params.append({})
    return params


def binarize_ste(w: jnp.ndarray) -> jnp.ndarray:
    """sign(w) in the forward pass, identity gradient (straight-through)."""
    w_bin = jnp.where(w >= 0, 1.0, -1.0)
    return w + jax.lax.stop_gradient(w_bin - w)


def deploy(params: list[dict[str, Any]], spec: ModelSpec) -> list[dict[str, Any]]:
    """Fold BN into quantized IF-BN and binarize weights (paper Eq. (4)).

    The first (encoding) layer's bias/theta are scaled by 255 because the
    deployed graph consumes raw u8 pixels while training consumed
    pixels / 255.
    """
    out: list[dict[str, Any]] = []
    for ly, p in zip(spec.layers, params):
        if ly.kind in ("enc_conv", "conv", "fc"):
            scale = 255.0 if ly.kind == "enc_conv" else 1.0
            bias_q, theta_q = ref.quantize_if_bn(
                p["gamma"], p["beta"], p["mu"], p["var"], p["v_th"],
                input_scale=scale, eps=BN_EPS,
            )
            out.append(
                dict(w=jnp.where(p["w"] >= 0, 1.0, -1.0), bias=bias_q, theta=theta_q)
            )
        elif ly.kind == "readout":
            out.append(dict(w=jnp.where(p["w"] >= 0, 1.0, -1.0)))
        else:
            out.append({})
    return out


# --------------------------------------------------------------------------
# Deployed (integer-exact) forward — the graph AOT-lowered for rust
# --------------------------------------------------------------------------


def forward_deployed(
    deployed: list[dict[str, Any]],
    spec: ModelSpec,
    image_u8: jnp.ndarray,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """Deployed inference for a single image.

    Parameters
    ----------
    deployed : output of :func:`deploy`.
    image_u8 : (C_in, H, W) raw pixels as integer-valued float32 in [0, 255].
    use_pallas : route convs/IF through the Pallas kernels (True) or the
        pure-jnp oracle (False); both are bit-identical.

    Returns
    -------
    (10,) integer-valued logits (accumulated readout membrane).
    """
    t_steps = spec.num_steps
    fp = float(FIXED_POINT)
    spikes: jnp.ndarray | None = None  # (T, C, H, W) once past the encoder

    for ly, p in zip(spec.layers, deployed):
        if ly.kind == "enc_conv":
            if use_pallas:
                x = encoding_conv2d(image_u8, p["w"])
            else:
                x = ref.conv2d_binary(image_u8, p["w"])
            psums = jnp.broadcast_to(fp * x, (t_steps,) + x.shape)
            ifd = if_dynamics if use_pallas else ref.if_dynamics
            spikes, _ = ifd(psums, p["bias"], p["theta"])
        elif ly.kind == "conv":
            if use_pallas:
                psums = fp * binary_conv2d_batched(spikes, p["w"])
                spikes, _ = if_dynamics(psums, p["bias"], p["theta"])
            else:
                psums = fp * ref.conv2d_binary_batched(spikes, p["w"])
                spikes, _ = ref.if_dynamics(psums, p["bias"], p["theta"])
        elif ly.kind == "maxpool":
            spikes = ref.maxpool2(spikes)
        elif ly.kind == "fc":
            flat = spikes.reshape(t_steps, -1)
            if use_pallas:
                psums = fp * binary_matmul(flat, p["w"])
                spikes, _ = if_dynamics_flat(psums, p["bias"], p["theta"])
            else:
                psums = fp * (flat @ p["w"].T)
                spikes, _ = ref.if_dynamics(psums, p["bias"], p["theta"])
            spikes = spikes.reshape(t_steps, -1, 1, 1)
        elif ly.kind == "readout":
            flat = spikes.reshape(t_steps, -1)
            if use_pallas:
                return binary_matmul(flat, p["w"]).sum(axis=0)
            return ref.readout_layer(flat, p["w"])
    raise ValueError("network has no readout layer")


def forward_deployed_batched(
    deployed: list[dict[str, Any]], spec: ModelSpec, images_u8: jnp.ndarray,
    use_pallas: bool = True,
) -> jnp.ndarray:
    """vmap of :func:`forward_deployed` over a batch of images."""
    return jax.vmap(
        lambda img: forward_deployed(deployed, spec, img, use_pallas=use_pallas)
    )(images_u8)


# --------------------------------------------------------------------------
# Training forward (float, differentiable, batch-stat BN) — STBP graph
# --------------------------------------------------------------------------

SURROGATE_WIDTH = 1.0  # rectangular surrogate window `a` (STBP [9])


def _fire_surrogate(v_pre: jnp.ndarray, theta: jnp.ndarray) -> jnp.ndarray:
    """Heaviside fire with rectangular surrogate d o/d v = 1(|v-th|<a/2)."""
    o_hard = (v_pre >= theta).astype(v_pre.dtype)
    window = (jnp.abs(v_pre - theta) < SURROGATE_WIDTH / 2).astype(v_pre.dtype)
    o_soft = window * (v_pre - theta)  # identity slope inside the window
    return o_soft + jax.lax.stop_gradient(o_hard - o_soft)


def _bn_stats(x: jnp.ndarray, axes: tuple[int, ...]) -> tuple[jnp.ndarray, jnp.ndarray]:
    mu = x.mean(axis=axes)
    var = x.var(axis=axes)
    return mu, var


def _if_train(psums: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Differentiable IF over (T, B, C, ...) psums with hard reset."""

    def step(v_res, x_t):
        v_pre = v_res + x_t
        o = _fire_surrogate(v_pre, jnp.asarray(theta, v_pre.dtype))
        return v_pre * (1.0 - o), o

    _, spikes = jax.lax.scan(step, jnp.zeros_like(psums[0]), psums)
    return spikes


def forward_train(
    params: list[dict[str, Any]], spec: ModelSpec, images: jnp.ndarray
) -> tuple[jnp.ndarray, list[tuple[jnp.ndarray, jnp.ndarray]]]:
    """STBP training forward over a batch.

    Parameters
    ----------
    images : (B, C_in, H, W) float in [0, 1].

    Returns
    -------
    logits : (B, 10) accumulated readout membrane.
    stats  : per weight-layer (mu, var) batch statistics for running-stat
             updates (zero-size entries for the readout layer).
    """
    t_steps = spec.num_steps
    batch = images.shape[0]
    stats: list[tuple[jnp.ndarray, jnp.ndarray]] = []
    spikes: jnp.ndarray | None = None  # (T, B, C, H, W)

    for ly, p in zip(spec.layers, params):
        if ly.kind == "enc_conv":
            w_bin = binarize_ste(p["w"])
            x = jax.vmap(lambda im: ref.conv2d_binary(im, w_bin))(images)  # (B,C,H,W)
            mu, var = _bn_stats(x, (0, 2, 3))
            stats.append((mu, var))
            xn = (x - mu[:, None, None]) / jnp.sqrt(var[:, None, None] + BN_EPS)
            xn = p["gamma"][:, None, None] * xn + p["beta"][:, None, None]
            psums = jnp.broadcast_to(xn, (t_steps,) + xn.shape)
            spikes = _if_train(psums, p["v_th"])
        elif ly.kind == "conv":
            w_bin = binarize_ste(p["w"])
            flat = spikes.reshape((-1,) + spikes.shape[2:])  # (T*B, C, H, W)
            x = jax.vmap(lambda s: ref.conv2d_binary(s, w_bin))(flat)
            mu, var = _bn_stats(x, (0, 2, 3))
            stats.append((mu, var))
            xn = (x - mu[:, None, None]) / jnp.sqrt(var[:, None, None] + BN_EPS)
            xn = p["gamma"][:, None, None] * xn + p["beta"][:, None, None]
            psums = xn.reshape((t_steps, batch) + x.shape[1:])
            spikes = _if_train(psums, p["v_th"])
        elif ly.kind == "maxpool":
            spikes = ref.maxpool2(spikes)
            stats.append((jnp.zeros(()), jnp.zeros(())))
        elif ly.kind == "fc":
            w_bin = binarize_ste(p["w"])
            flat = spikes.reshape(t_steps, batch, -1)
            x = flat @ w_bin.T  # (T, B, N_out)
            mu, var = _bn_stats(x.reshape(-1, x.shape[-1]), (0,))
            stats.append((mu, var))
            xn = (x - mu) / jnp.sqrt(var + BN_EPS)
            xn = p["gamma"] * xn + p["beta"]
            spikes = _if_train(xn, p["v_th"])[..., None, None]
        elif ly.kind == "readout":
            w_bin = binarize_ste(p["w"])
            flat = spikes.reshape(t_steps, batch, -1)
            stats.append((jnp.zeros(()), jnp.zeros(())))
            return (flat @ w_bin.T).sum(axis=0), stats
    raise ValueError("network has no readout layer")


def forward_train_ann(
    params: list[dict[str, Any]], spec: ModelSpec, images: jnp.ndarray
) -> jnp.ndarray:
    """Full-precision ANN twin (ReLU instead of IF, same topology).

    The Fig. 8 baseline: identical layer stack, float weights, BN + ReLU,
    no time dimension.
    """
    x = images  # (B, C, H, W)
    for ly, p in zip(spec.layers, params):
        if ly.kind in ("enc_conv", "conv"):
            x = jax.vmap(lambda im, w=p["w"]: ref.conv2d_binary(im, w))(x)
            mu, var = _bn_stats(x, (0, 2, 3))
            xn = (x - mu[:, None, None]) / jnp.sqrt(var[:, None, None] + BN_EPS)
            x = jax.nn.relu(p["gamma"][:, None, None] * xn + p["beta"][:, None, None])
        elif ly.kind == "maxpool":
            x = ref.maxpool2(x)
        elif ly.kind == "fc":
            flat = x.reshape(x.shape[0], -1)
            h = flat @ p["w"].T
            mu, var = _bn_stats(h, (0,))
            x = jax.nn.relu(p["gamma"] * (h - mu) / jnp.sqrt(var + BN_EPS) + p["beta"])
            x = x[..., None, None]
        elif ly.kind == "readout":
            return x.reshape(x.shape[0], -1) @ p["w"].T
    raise ValueError("network has no readout layer")

"""VSAW binary parameter format — the weight interchange with rust.

``deploy()``-ed models (binary weights + quantized IF-BN bias/theta) are
serialized to a little-endian binary format that ``rust/src/snn/params.rs``
reads, so the JAX model, the rust golden model and the cycle-accurate
simulator all run the *same* network.

Layout (all integers little-endian)
-----------------------------------
    magic      : 4 bytes  b"VSAW"
    version    : u32      (currently 1)
    name_len   : u32, name bytes (utf-8)
    num_steps  : u32      (T)
    in_ch      : u32, in_size : u32
    num_layers : u32
    per layer:
      kind : u8   0=enc_conv 1=conv 2=maxpool 3=fc 4=readout
      enc_conv/conv : c_out u32, c_in u32, k u32,
                      weights i8[c_out*c_in*k*k]   (+1 / -1),
                      bias  i32[c_out], theta i32[c_out]
      fc            : n_out u32, n_in u32, weights i8[n_out*n_in],
                      bias i32[n_out], theta i32[n_out]
      readout       : n_out u32, n_in u32, weights i8[n_out*n_in]
      maxpool       : (no payload)

bias/theta are the *quantized* values (already premultiplied by
``FIXED_POINT``), stored as i32.
"""

from __future__ import annotations

import struct
from typing import Any

import numpy as np

from .model import ModelSpec

MAGIC = b"VSAW"
VERSION = 1
KIND_CODE = {"enc_conv": 0, "conv": 1, "maxpool": 2, "fc": 3, "readout": 4}


def save_deployed(
    path: str, deployed: list[dict[str, Any]], spec: ModelSpec
) -> None:
    """Serialize a deployed model to ``path`` in VSAW v1 format."""
    out = bytearray()
    out += MAGIC
    out += struct.pack("<I", VERSION)
    name = spec.name.encode()
    out += struct.pack("<I", len(name)) + name
    out += struct.pack("<III", spec.num_steps, spec.in_channels, spec.in_size)
    out += struct.pack("<I", len(spec.layers))

    for ly, p in zip(spec.layers, deployed):
        out += struct.pack("<B", KIND_CODE[ly.kind])
        if ly.kind in ("enc_conv", "conv"):
            w = np.asarray(p["w"], dtype=np.float32)
            c_out, c_in, k, _ = w.shape
            out += struct.pack("<III", c_out, c_in, k)
            out += w.astype(np.int8).tobytes()
            out += np.asarray(p["bias"], dtype=np.int32).tobytes()
            out += np.asarray(p["theta"], dtype=np.int32).tobytes()
        elif ly.kind == "fc":
            w = np.asarray(p["w"], dtype=np.float32)
            n_out, n_in = w.shape
            out += struct.pack("<II", n_out, n_in)
            out += w.astype(np.int8).tobytes()
            out += np.asarray(p["bias"], dtype=np.int32).tobytes()
            out += np.asarray(p["theta"], dtype=np.int32).tobytes()
        elif ly.kind == "readout":
            w = np.asarray(p["w"], dtype=np.float32)
            n_out, n_in = w.shape
            out += struct.pack("<II", n_out, n_in)
            out += w.astype(np.int8).tobytes()
    with open(path, "wb") as f:
        f.write(bytes(out))


def load_deployed(path: str) -> tuple[str, int, int, int, list[dict[str, Any]]]:
    """Read a VSAW file back; returns (name, T, in_ch, in_size, layers).

    Each layer dict carries ``kind`` plus float32 arrays matching what
    ``deploy()`` produces — used by round-trip tests.
    """
    with open(path, "rb") as f:
        buf = f.read()
    off = 0

    def take(fmt: str):
        nonlocal off
        vals = struct.unpack_from("<" + fmt, buf, off)
        off += struct.calcsize("<" + fmt)
        return vals if len(vals) > 1 else vals[0]

    assert buf[:4] == MAGIC, "bad magic"
    off = 4
    version = take("I")
    assert version == VERSION, f"unsupported version {version}"
    name_len = take("I")
    name = buf[off : off + name_len].decode()
    off += name_len
    num_steps, in_ch, in_size = take("III")
    num_layers = take("I")

    def take_arr(dtype, count):
        nonlocal off
        arr = np.frombuffer(buf, dtype=dtype, count=count, offset=off)
        off += arr.nbytes
        return arr

    code_kind = {v: k for k, v in KIND_CODE.items()}
    layers: list[dict[str, Any]] = []
    for _ in range(num_layers):
        kind = code_kind[take("B")]
        if kind in ("enc_conv", "conv"):
            c_out, c_in, k = take("III")
            w = take_arr(np.int8, c_out * c_in * k * k).reshape(c_out, c_in, k, k)
            bias = take_arr(np.int32, c_out)
            theta = take_arr(np.int32, c_out)
            layers.append(
                dict(kind=kind, w=w.astype(np.float32),
                     bias=bias.astype(np.float32), theta=theta.astype(np.float32))
            )
        elif kind == "fc":
            n_out, n_in = take("II")
            w = take_arr(np.int8, n_out * n_in).reshape(n_out, n_in)
            bias = take_arr(np.int32, n_out)
            theta = take_arr(np.int32, n_out)
            layers.append(
                dict(kind=kind, w=w.astype(np.float32),
                     bias=bias.astype(np.float32), theta=theta.astype(np.float32))
            )
        elif kind == "readout":
            n_out, n_in = take("II")
            w = take_arr(np.int8, n_out * n_in).reshape(n_out, n_in)
            layers.append(dict(kind=kind, w=w.astype(np.float32)))
        else:
            layers.append(dict(kind=kind))
    return name, num_steps, in_ch, in_size, layers

"""Deterministic synthetic datasets (MNIST-like / CIFAR-like).

This environment has no network access, so the repo ships procedural
stand-ins for MNIST and CIFAR-10 (DESIGN.md §Substitutions): 10-class
integer-exact pattern generators whose pixels are produced purely with
64-bit integer arithmetic (splitmix64), so ``rust/src/data/synth.rs``
regenerates *bit-identical* images — the cross-language contract used by
the integration tests and the serving benchmarks.

Each class has a distinct quasi-periodic integer template; each sample adds
a per-sample circular shift and additive noise.  The task is genuinely
learnable (a linear probe gets well above chance; the SNN does much
better), which is all Fig. 8 / Table II need to reproduce the paper's
*trends* (accuracy vs time steps, binary vs full precision).

If real ``data/mnist/*-idx?-ubyte`` or CIFAR binaries are present, loaders
in rust pick those up instead; the python side stays synthetic-only.
"""

from __future__ import annotations

import numpy as np

_M64 = (1 << 64) - 1


def splitmix64(state: int) -> tuple[int, int]:
    """One splitmix64 step: returns (new_state, output). Pure integer ops."""
    state = (state + 0x9E3779B97F4A7C15) & _M64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _M64
    return state, (z ^ (z >> 31)) & _M64


# Per-class template coefficients (primes; identical table in rust).
_P1 = [3, 5, 7, 11, 13, 17, 19, 23, 29, 31]
_P2 = [7, 3, 11, 5, 17, 13, 23, 19, 37, 29]
_P3 = [0, 9, 4, 13, 6, 15, 2, 11, 8, 17]


def template_pixel(cls: int, ch: int, x: int, y: int) -> int:
    """Deterministic class template pixel in [0, 255].

    Quasi-periodic diagonal bands whose period/phase depend on the class
    and channel — visually distinct stripes/checker mixes per class.
    """
    a = (x * _P1[cls] + y * _P2[cls] + _P3[cls] + ch * 5) % 29
    b = 64 if ((x // 4 + y // 4 + cls + ch) % 3) == 0 else 0
    return min(255, a * 7 + b)


def synth_image(
    seed: int, index: int, cls: int, channels: int, size: int
) -> np.ndarray:
    """Generate one (channels, size, size) u8 image for class ``cls``.

    Per-sample variation: circular shift dx,dy in [-3, 3] and additive
    noise in [-32, 31], all drawn from splitmix64 seeded by
    ``seed*1e6 XOR index`` — matching rust exactly.
    """
    state = (seed * 1_000_003 + index * 7919 + cls) & _M64
    state, z = splitmix64(state)
    dx = int(z % 7) - 3
    state, z = splitmix64(state)
    dy = int(z % 7) - 3

    img = np.empty((channels, size, size), dtype=np.uint8)
    for c in range(channels):
        for yy in range(size):
            for xx in range(size):
                sx = (xx + dx) % size
                sy = (yy + dy) % size
                state, z = splitmix64(state)
                noise = int(z % 64) - 32
                v = template_pixel(cls, c, sx, sy) + noise
                img[c, yy, xx] = max(0, min(255, v))
    return img


def synth_batch(
    seed: int, start: int, count: int, channels: int, size: int
) -> tuple[np.ndarray, np.ndarray]:
    """Generate ``count`` images with balanced labels ``(start+i) % 10``.

    Returns (images u8 (count, C, S, S), labels i32 (count,)).
    """
    imgs = np.empty((count, channels, size, size), dtype=np.uint8)
    labels = np.empty(count, dtype=np.int32)
    for i in range(count):
        cls = (start + i) % 10
        imgs[i] = synth_image(seed, start + i, cls, channels, size)
        labels[i] = cls
    return imgs, labels


def mnist_like(seed: int, start: int, count: int) -> tuple[np.ndarray, np.ndarray]:
    """(count, 1, 28, 28) u8 images + labels."""
    return synth_batch(seed, start, count, 1, 28)


def cifar_like(seed: int, start: int, count: int) -> tuple[np.ndarray, np.ndarray]:
    """(count, 3, 32, 32) u8 images + labels."""
    return synth_batch(seed, start, count, 3, 32)


def tiny_like(seed: int, start: int, count: int) -> tuple[np.ndarray, np.ndarray]:
    """(count, 1, 12, 12) u8 images + labels, for the tiny test net."""
    return synth_batch(seed, start, count, 1, 12)


FOR_SPEC = {"mnist": mnist_like, "cifar10": cifar_like, "tiny": tiny_like}

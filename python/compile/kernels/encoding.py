"""Pallas kernel: multi-bit encoding-layer convolution via bitplanes.

Hardware mapping (paper §III-E, Fig. 7)
---------------------------------------
The chip supports the multi-bit encoding layer on the *binary* PE datapath
by splitting each 8-bit input into eight 1-bit bitplanes, assigning each
bitplane to one PE block (so eight blocks share one weight vector), and
shift-adding the per-plane partial sums in the first accumulator stage.

The kernel reproduces that identity directly: bitplane extraction, binary
convolution per plane on the same sign-select datapath as
``binary_conv.py``, then the power-of-two weighted reduction.  The result
is exactly ``conv(image, w)`` for integer images in ``[0, 2**num_planes)``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CO_TILE = 64


def _encoding_kernel(
    x_ref, w_ref, o_ref, *, ksize: int, height: int, width: int, num_planes: int
):
    """One output-channel tile of the bitplane-decomposed encoding conv.

    x_ref : (C_in, H + K - 1, W + K - 1) pre-padded multi-bit input.
    w_ref : (tile_co, C_in, K, K) binary weights.
    o_ref : (tile_co, H, W) multi-bit psums.
    """
    x_int = x_ref[...].astype(jnp.int32)
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for plane in range(num_planes):
        # 1-bit plane on the binary datapath (one PE block per plane).
        bit = ((x_int >> plane) & 1).astype(jnp.float32)
        plane_acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
        for kh in range(ksize):
            for kw in range(ksize):
                slab = bit[:, kh : kh + height, kw : kw + width]
                w_col = w_ref[:, :, kh, kw]
                plane_acc = plane_acc + jax.lax.dot_general(
                    w_col,
                    slab.reshape(slab.shape[0], -1),
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ).reshape(plane_acc.shape)
        # First-stage accumulator shift-add: psum << plane.
        acc = acc + float(1 << plane) * plane_acc
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("num_planes", "co_tile"))
def encoding_conv2d(
    image: jnp.ndarray,
    w: jnp.ndarray,
    num_planes: int = 8,
    co_tile: int = DEFAULT_CO_TILE,
) -> jnp.ndarray:
    """Encoding-layer conv on the binary datapath (bitplane shift-add).

    Parameters
    ----------
    image : (C_in, H, W) integer-valued non-negative input in
            ``[0, 2**num_planes)`` (the paper normalizes inputs to be
            positive so the bitplane trick applies).
    w : (C_out, C_in, K, K) binary weights.

    Returns
    -------
    (C_out, H, W) psums, bit-identical to ``ref.conv2d_binary(image, w)``.
    """
    c_out, c_in, k, _ = w.shape
    _, h, wd = image.shape
    pad = k // 2
    xp = jnp.pad(image, ((0, 0), (pad, pad), (pad, pad)))

    tile = min(co_tile, c_out)
    if c_out % tile != 0:
        tile = c_out

    kernel = functools.partial(
        _encoding_kernel, ksize=k, height=h, width=wd, num_planes=num_planes
    )
    return pl.pallas_call(
        kernel,
        grid=(c_out // tile,),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec((tile, c_in, k, k), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, h, wd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c_out, h, wd), jnp.float32),
        interpret=True,
    )(xp, w)

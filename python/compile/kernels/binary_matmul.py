"""Pallas kernel: binary-weight matmul for the spiking FC layers.

The chip schedules fully-connected layers on the same vectorwise PE fabric
(a weight column vector against a spike vector); here that is a tiled
matmul with +-1 weights.  Grid tiles the output-neuron axis the way PE
blocks tile output channels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_N_TILE = 128


def _matmul_kernel(s_ref, w_ref, o_ref):
    """s_ref: (T, N_in) spikes; w_ref: (tile_n, N_in); o_ref: (T, tile_n)."""
    o_ref[...] = jax.lax.dot_general(
        s_ref[...],
        w_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("n_tile",))
def binary_matmul(
    spikes: jnp.ndarray, w: jnp.ndarray, n_tile: int = DEFAULT_N_TILE
) -> jnp.ndarray:
    """Per-step FC psums: ``spikes @ w.T`` with binary weights.

    Parameters
    ----------
    spikes : (T, N_in) 0/1 spike train.
    w      : (N_out, N_in) binary (+-1) weights.

    Returns
    -------
    (T, N_out) integer-valued psums, bit-identical to ``spikes @ w.T``.
    """
    t_steps, n_in = spikes.shape
    n_out = w.shape[0]
    tile = min(n_tile, n_out)
    if n_out % tile != 0:
        tile = n_out

    return pl.pallas_call(
        _matmul_kernel,
        grid=(n_out // tile,),
        in_specs=[
            pl.BlockSpec((t_steps, n_in), lambda i: (0, 0)),
            pl.BlockSpec((tile, n_in), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((t_steps, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((t_steps, n_out), jnp.float32),
        interpret=True,
    )(spikes, w)

"""Pallas kernel: vectorwise binary-weight convolution (paper Fig. 3-6).

Hardware mapping (DESIGN.md §Hardware-Adaptation)
-------------------------------------------------
The VSA chip broadcasts one *column vector* of input spikes against one
column vector of binary weights per cycle and reduces products along the PE
diagonal, so every PE contributes every cycle.  On the TPU-flavoured side
we express the same schedule as:

* grid over output-channel tiles — the analogue of the 32 PE blocks each
  owning a channel group (channel groups > tile are sequenced by the grid,
  exactly like the chip's group-of-32 sequencing through the accumulator);
* for each (kh, kw) tap, a *weight column* ``w[:, :, kh, kw]`` of shape
  ``(tile_co, C_in)`` is contracted against the shifted input slab — a
  plain MXU-shaped matmul over the input-channel axis, the vectorwise
  product the PE array computes with AND gates + diagonal adders;
* binary multiply is sign-select, not a float multiply: weights are +-1 so
  the contraction is exact integer arithmetic in f32.

The kernel is lowered with ``interpret=True`` (CPU PJRT cannot execute
Mosaic custom-calls); real-TPU VMEM/MXU estimates live in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-channel tile: mirrors the 32-PE-block channel grouping of the chip.
DEFAULT_CO_TILE = 64


def _conv_kernel(x_ref, w_ref, o_ref, *, ksize: int, height: int, width: int):
    """One grid step: one output-channel tile over the full feature map.

    x_ref : (C_in, H + K - 1, W + K - 1) pre-padded input in VMEM.
    w_ref : (tile_co, C_in, K, K) binary weight block in VMEM.
    o_ref : (tile_co, H, W) output psum block.
    """
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    # Static K x K tap loop — unrolled at trace time; each tap is one
    # "weight column broadcast" of the vectorwise dataflow.
    for kh in range(ksize):
        for kw in range(ksize):
            # (C_in, H, W) shifted input slab for this tap.
            slab = x_ref[:, kh : kh + height, kw : kw + width]
            # (tile_co, C_in) weight column vector.
            w_col = w_ref[:, :, kh, kw]
            # Diagonal reduction of the PE array == contraction over C_in.
            acc = acc + jax.lax.dot_general(
                w_col,
                slab.reshape(slab.shape[0], -1),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(acc.shape)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("co_tile",))
def binary_conv2d(
    x: jnp.ndarray, w: jnp.ndarray, co_tile: int = DEFAULT_CO_TILE
) -> jnp.ndarray:
    """'Same'-padded stride-1 binary-weight conv via the vectorwise kernel.

    Parameters
    ----------
    x : (C_in, H, W) spikes (0/1) or multi-bit planes, float32.
    w : (C_out, C_in, K, K) binary weights (+-1.0), float32.
    co_tile : output-channel tile width (chip analogue: PE-block group).

    Returns
    -------
    (C_out, H, W) integer-valued float32 psums, bit-identical to
    ``ref.conv2d_binary``.
    """
    c_out, c_in, k, _ = w.shape
    _, h, wd = x.shape
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))

    tile = min(co_tile, c_out)
    if c_out % tile != 0:
        tile = c_out  # fall back to a single tile for ragged channel counts

    kernel = functools.partial(_conv_kernel, ksize=k, height=h, width=wd)
    return pl.pallas_call(
        kernel,
        grid=(c_out // tile,),
        in_specs=[
            # Full padded input replicated to every channel-tile grid step:
            # the chip broadcasts the same spike vector to all PE blocks.
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec((tile, c_in, k, k), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, h, wd), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c_out, h, wd), jnp.float32),
        interpret=True,
    )(xp, w)


def _conv_kernel_t(x_ref, w_ref, o_ref, *, ksize: int, height: int, width: int):
    """Time-batched grid step: x_ref (1, C_in, Hp, Wp), o_ref (1, tile, H, W)."""
    acc = jnp.zeros(o_ref.shape[1:], dtype=jnp.float32)
    for kh in range(ksize):
        for kw in range(ksize):
            slab = x_ref[0, :, kh : kh + height, kw : kw + width]
            w_col = w_ref[:, :, kh, kw]
            acc = acc + jax.lax.dot_general(
                w_col,
                slab.reshape(slab.shape[0], -1),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(acc.shape)
    o_ref[...] = acc[None]


@functools.partial(jax.jit, static_argnames=("co_tile",))
def binary_conv2d_batched(
    x: jnp.ndarray, w: jnp.ndarray, co_tile: int = DEFAULT_CO_TILE
) -> jnp.ndarray:
    """Conv over a (T, C_in, H, W) spike train in ONE pallas invocation.

    The time axis joins the grid (tick batching at the kernel level: the
    whole T-loop stays inside one kernel launch, like the chip processing
    all time steps of a layer back-to-back), which is ~1.2x faster under
    the interpret-mode executor than vmapping T separate calls.
    """
    t_steps, _, h, wd = x.shape
    c_out, c_in, k, _ = w.shape
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))

    tile = min(co_tile, c_out)
    if c_out % tile != 0:
        tile = c_out

    kernel = functools.partial(_conv_kernel_t, ksize=k, height=h, width=wd)
    return pl.pallas_call(
        kernel,
        grid=(t_steps, c_out // tile),
        in_specs=[
            pl.BlockSpec((1,) + xp.shape[1:], lambda t, i: (t, 0, 0, 0)),
            pl.BlockSpec((tile, c_in, k, k), lambda t, i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile, h, wd), lambda t, i: (t, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((t_steps, c_out, h, wd), jnp.float32),
        interpret=True,
    )(xp, w)

"""Pallas kernel: integrate-and-fire dynamics with IF-based BatchNorm.

Hardware mapping
----------------
The chip's IF neuron unit (paper Fig. 1(b), §III-F) reads the convolution
result, accumulates it with the residual membrane potential held in the
membrane SRAM, compares against the per-channel IF-BN threshold, fires and
hard-resets.  *Tick batching* keeps the membrane on-chip across all T time
steps of a layer.

Here the membrane lives in a kernel-local carry (the VMEM-scratch analogue
of the membrane SRAM) inside a ``fori_loop`` over T, so the whole time loop
stays inside one kernel invocation — psums stream in once, spikes stream
out once, and the membrane never round-trips to HBM.  The grid tiles the
channel axis, mirroring the chip's channelwise neuron banks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_C_TILE = 64


def _if_kernel(p_ref, b_ref, th_ref, o_ref, v_ref, *, num_steps: int):
    """One channel tile, full time loop.

    p_ref  : (T, tile_c, H, W) psums.
    b_ref  : (tile_c,) IF-BN bias.
    th_ref : (tile_c,) IF-BN threshold.
    o_ref  : (T, tile_c, H, W) output spikes.
    v_ref  : (tile_c, H, W) residual membrane after step T-1.
    """
    bias = b_ref[...][:, None, None]
    theta = th_ref[...][:, None, None]

    def step(t, v_res):
        x_t = p_ref[t]
        v_pre = v_res + (x_t - bias)
        o = (v_pre >= theta).astype(jnp.float32)
        o_ref[t] = o
        return v_pre * (1.0 - o)  # hard reset (Eq. (1))

    v_final = jax.lax.fori_loop(
        0, num_steps, step, jnp.zeros(v_ref.shape, jnp.float32)
    )
    v_ref[...] = v_final


@functools.partial(jax.jit, static_argnames=("c_tile",))
def if_dynamics(
    psums: jnp.ndarray,
    bias: jnp.ndarray,
    theta: jnp.ndarray,
    c_tile: int = DEFAULT_C_TILE,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """IF neuron over a psum sequence; bit-identical to ``ref.if_dynamics``.

    Parameters
    ----------
    psums : (T, C, H, W) per-step convolution outputs.
    bias, theta : (C,) quantized IF-BN parameters.

    Returns
    -------
    (spikes (T, C, H, W), v_res (C, H, W)).
    """
    t_steps, c, h, w = psums.shape
    tile = min(c_tile, c)
    if c % tile != 0:
        tile = c

    kernel = functools.partial(_if_kernel, num_steps=t_steps)
    return pl.pallas_call(
        kernel,
        grid=(c // tile,),
        in_specs=[
            pl.BlockSpec((t_steps, tile, h, w), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((t_steps, tile, h, w), lambda i: (0, i, 0, 0)),
            pl.BlockSpec((tile, h, w), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_steps, c, h, w), jnp.float32),
            jax.ShapeDtypeStruct((c, h, w), jnp.float32),
        ],
        interpret=True,
    )(psums, bias, theta)


def if_dynamics_flat(
    psums: jnp.ndarray, bias: jnp.ndarray, theta: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """IF dynamics for (T, N) fully-connected psums.

    Reshapes through the 4-D kernel so FC layers share the same datapath,
    like the chip reusing its neuron unit for fc layers.
    """
    t_steps, n = psums.shape
    sp, v = if_dynamics(psums.reshape(t_steps, n, 1, 1), bias, theta)
    return sp.reshape(t_steps, n), v.reshape(n)

"""Pure-jnp reference oracles for the VSA kernels.

These functions define the *numerical contract* of the whole stack: the
Pallas kernels (``binary_conv.py``, ``if_neuron.py``, ``encoding.py``), the
JAX model (``compile/model.py``), the rust functional golden model
(``rust/src/snn/``) and the cycle-accurate simulator (``rust/src/arch/``)
must all agree with these bit-for-bit on the deployed integer domain.

Conventions
-----------
* Tensors are NCHW; a leading ``T`` axis is the SNN time dimension.
* Binary weights are carried as float ``+1.0`` / ``-1.0`` (the hardware
  stores the sign bit; ``-1 -> 1``, ``+1 -> 0``).
* Spikes are ``0.0`` / ``1.0`` floats.
* All deployed quantities are *integer-valued floats*: convolution sums of
  binary products are integers, and IF-BN biases/thresholds are quantized
  to a ``FIXED_POINT`` fixed-point grid so every membrane value is an
  integer.  Every value stays well below 2**24, so float32 arithmetic is
  exact and cross-language equality is meaningful.

IF neuron (paper Eq. (1)-(2), hard reset)
-----------------------------------------
    V_pre[t] = V_res[t-1] + (x[t] - bias)
    o[t]     = 1  if V_pre[t] >= theta  else 0
    V_res[t] = V_pre[t] * (1 - o[t])

IF-based BatchNorm (paper Eq. (3)-(4)) folds BN(gamma, beta, mu, sigma)
followed by threshold ``Vth`` into ``bias = mu - sigma/gamma * beta`` and
``theta = sigma/gamma * Vth`` (``gamma > 0`` is enforced during training).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Fixed-point scale for IF-BN bias/threshold quantization.  Membrane
# potentials live on the integer grid ``1/FIXED_POINT`` of the conv-output
# unit; see ``quantize_if_bn``.
FIXED_POINT = 256


def conv2d_binary(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """'Same'-padded stride-1 2-D convolution with binary (+-1) weights.

    Parameters
    ----------
    x : (C_in, H, W) input feature map (spikes or multi-bit planes).
    w : (C_out, C_in, K, K) binary weights (+-1.0).

    Returns
    -------
    (C_out, H, W) integer-valued partial sums.
    """
    lhs = x[None]  # (1, C_in, H, W)
    k = w.shape[-1]
    out = jax.lax.conv_general_dilated(
        lhs,
        w,
        window_strides=(1, 1),
        padding=[(k // 2, k // 2), (k // 2, k // 2)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0]


def conv2d_binary_batched(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Batched variant of :func:`conv2d_binary` over a leading axis."""
    return jax.vmap(lambda xt: conv2d_binary(xt, w))(x)


def if_dynamics(
    psums: jnp.ndarray, bias: jnp.ndarray, theta: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Integrate-and-fire over a psum sequence (paper Eq. (1)-(2)).

    Parameters
    ----------
    psums : (T, C, ...) per-time-step convolution outputs.
    bias  : (C,) IF-BN bias, broadcast over spatial dims.
    theta : (C,) IF-BN firing threshold (> 0).

    Returns
    -------
    spikes : (T, C, ...) 0/1 spike train.
    v_res  : (C, ...) residual membrane potential after the last step.
    """
    cshape = (-1,) + (1,) * (psums.ndim - 2)
    b = bias.reshape(cshape)
    th = theta.reshape(cshape)

    def step(v_res, x_t):
        v_pre = v_res + (x_t - b)
        o = (v_pre >= th).astype(psums.dtype)
        return v_pre * (1.0 - o), o

    v_res, spikes = jax.lax.scan(step, jnp.zeros_like(psums[0]), psums)
    return spikes, v_res


def encoding_layer(
    image: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    theta: jnp.ndarray,
    num_steps: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Encoding layer (paper §III-E/F): conv once, IF-fire ``num_steps`` times.

    The multi-bit image is convolved a single time; the (identical) result
    is accumulated into the membrane at every time step, generating the
    spike train for the first spiking layer.

    Parameters
    ----------
    image : (C_in, H, W) multi-bit non-negative input (integer-valued).
    w     : (C_out, C_in, K, K) binary weights.
    bias, theta : (C_out,) IF-BN parameters in *input-scale* units.
    num_steps : T, number of time steps to emit.
    """
    x = conv2d_binary(image, w)
    psums = jnp.broadcast_to(x, (num_steps,) + x.shape)
    return if_dynamics(psums, bias, theta)


def encoding_layer_bitplanes(
    image: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    theta: jnp.ndarray,
    num_steps: int,
    num_planes: int = 8,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bitplane-decomposed encoding layer (paper Fig. 7).

    Splits the 8-bit input into ``num_planes`` binary planes, convolves each
    with the *same* binary weights on the binary datapath, and shift-adds
    the plane results — the arithmetic identity the chip's first-stage
    accumulator implements.  Must equal :func:`encoding_layer` exactly.
    """
    img_i = image.astype(jnp.int32)
    planes = [((img_i >> p) & 1).astype(image.dtype) for p in range(num_planes)]
    x = sum(float(1 << p) * conv2d_binary(planes[p], w) for p in range(num_planes))
    psums = jnp.broadcast_to(x, (num_steps,) + x.shape)
    return if_dynamics(psums, bias, theta)


def spiking_conv_layer(
    spikes_in: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    theta: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Spiking conv layer: per-step binary conv + IF dynamics.

    Parameters
    ----------
    spikes_in : (T, C_in, H, W) input spike train.
    """
    psums = conv2d_binary_batched(spikes_in, w)
    return if_dynamics(psums, bias, theta)


def maxpool2(spikes: jnp.ndarray) -> jnp.ndarray:
    """2x2/stride-2 max pool over the trailing two dims (OR on spikes)."""
    t_lead = spikes.shape[:-2]
    h, w = spikes.shape[-2:]
    x = spikes.reshape(t_lead + (h // 2, 2, w // 2, 2))
    return x.max(axis=(-3, -1))


def spiking_fc_layer(
    spikes_in: jnp.ndarray,
    w: jnp.ndarray,
    bias: jnp.ndarray,
    theta: jnp.ndarray,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Spiking fully-connected layer.

    Parameters
    ----------
    spikes_in : (T, N_in) flattened spike train.
    w         : (N_out, N_in) binary weights.
    """
    psums = spikes_in @ w.T
    return if_dynamics(psums, bias, theta)


def readout_layer(spikes_in: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Final non-firing layer: accumulate membrane over all T -> logits.

    Parameters
    ----------
    spikes_in : (T, N_in) spike train from the last hidden layer.
    w         : (N_classes, N_in) binary weights.

    Returns
    -------
    (N_classes,) accumulated membrane potential (the classification logits).
    """
    return (spikes_in @ w.T).sum(axis=0)


def quantize_if_bn(
    gamma: jnp.ndarray,
    beta: jnp.ndarray,
    mu: jnp.ndarray,
    var: jnp.ndarray,
    v_th: float,
    input_scale: float = 1.0,
    eps: float = 1e-5,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fold BN + threshold into quantized IF-BN (bias, theta) (Eq. (4)).

    ``input_scale`` rescales train-time normalized units to the deployed
    integer domain (255 for the encoding layer, 1 for spiking layers).
    Outputs are integer-valued floats on the ``1/FIXED_POINT`` grid,
    *pre-multiplied* by ``FIXED_POINT`` — i.e. deployed membrane arithmetic
    is ``FIXED_POINT * conv_out - bias_q`` compared against ``theta_q``.
    The un-quantized float path divides both by ``FIXED_POINT`` again, so
    ``if_dynamics(psums, bias_q / FP, theta_q / FP)`` matches the integer
    hardware exactly when ``psums`` are integer-valued.
    """
    sigma = jnp.sqrt(var + eps)
    bias = mu - sigma / gamma * beta
    theta = sigma / gamma * v_th
    bias_q = jnp.round(bias * input_scale * FIXED_POINT)
    theta_q = jnp.maximum(jnp.round(theta * input_scale * FIXED_POINT), 1.0)
    return bias_q, theta_q

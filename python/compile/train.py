"""STBP direct training of the binary-weight spiking model (paper §II).

Spatio-temporal backprop [9] through the differentiable training view of
``model.py`` (rectangular surrogate, straight-through binarization [10]),
with a hand-rolled Adam (optax is not available in this environment).

Trainable leaves: latent conv/fc weights, BN gamma/beta.  BN running
statistics (mu, var) are tracked with momentum and folded into IF-BN at
deploy time (paper Eq. (4)).  ``gamma`` is clamped positive so the folded
threshold stays positive and the firing inequality keeps its direction.

CLI
---
    python -m compile.train --spec tiny --steps 300 --batch 32 \
        --out ../artifacts/tiny_trained.vsaw
    python -m compile.train --fig8 --spec tiny --steps 200

``--fig8`` sweeps time steps T and prints the ANN-vs-SNN accuracy series
of paper Fig. 8 (on the synthetic datasets; see DESIGN.md §Substitutions).
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, params_io
from .model import (
    SPECS,
    ModelSpec,
    deploy,
    forward_deployed_batched,
    forward_train,
    forward_train_ann,
    init_params,
)

BN_MOMENTUM = 0.9
GAMMA_MIN = 0.05


# --------------------------------------------------------------------------
# Hand-rolled Adam over the params pytree
# --------------------------------------------------------------------------

TRAINABLE_KEYS = ("w", "gamma", "beta")


def adam_init(params: list[dict[str, Any]]) -> dict[str, Any]:
    """Zero first/second moments for every trainable leaf."""
    zeros = [
        {k: jnp.zeros_like(p[k]) for k in TRAINABLE_KEYS if k in p} for p in params
    ]
    return dict(m=zeros, v=[{k: jnp.zeros_like(x[k]) for k in x} for x in zeros], t=0)


def adam_step(
    params: list[dict[str, Any]],
    grads: list[dict[str, Any]],
    state: dict[str, Any],
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> tuple[list[dict[str, Any]], dict[str, Any]]:
    """One Adam update; returns (new_params, new_state)."""
    t = state["t"] + 1
    new_params, new_m, new_v = [], [], []
    for p, g, m, v in zip(params, grads, state["m"], state["v"]):
        np_, nm, nv = dict(p), {}, {}
        for k in m:
            gk = g.get(k, jnp.zeros_like(p[k]))
            nm[k] = b1 * m[k] + (1 - b1) * gk
            nv[k] = b2 * v[k] + (1 - b2) * gk * gk
            mhat = nm[k] / (1 - b1**t)
            vhat = nv[k] / (1 - b2**t)
            np_[k] = p[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        if "gamma" in np_:
            np_["gamma"] = jnp.maximum(np_["gamma"], GAMMA_MIN)
        new_params.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return new_params, dict(m=new_m, v=new_v, t=t)


# --------------------------------------------------------------------------
# Loss / metrics
# --------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy; logits scaled by 1/T-ish for stability."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -logp[jnp.arange(labels.shape[0]), labels].mean()


def accuracy(logits: np.ndarray, labels: np.ndarray) -> float:
    return float((logits.argmax(-1) == labels).mean())


# --------------------------------------------------------------------------
# Training loops
# --------------------------------------------------------------------------


def make_snn_step(spec: ModelSpec, lr: float):
    """Build the jitted STBP train step (loss + grads + BN stat update)."""

    def loss_fn(params, images, labels):
        logits, stats = forward_train(params, spec, images)
        return cross_entropy(logits / spec.num_steps, labels), (logits, stats)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    @jax.jit
    def step(params, opt, images, labels):
        (loss, (logits, stats)), grads = grad_fn(params, images, labels)
        params, opt = adam_step(params, grads, opt, lr)
        # BN running-stat EMA for deployment.
        new_params = []
        for p, st in zip(params, stats):
            if "mu" in p and st[0].ndim > 0:
                p = dict(
                    p,
                    mu=BN_MOMENTUM * p["mu"] + (1 - BN_MOMENTUM) * st[0],
                    var=BN_MOMENTUM * p["var"] + (1 - BN_MOMENTUM) * st[1],
                )
            new_params.append(p)
        return new_params, opt, loss, logits

    return step


def make_ann_step(spec: ModelSpec, lr: float):
    """Train step for the full-precision ANN twin (Fig. 8 baseline)."""

    def loss_fn(params, images, labels):
        logits = forward_train_ann(params, spec, images)
        return cross_entropy(logits, labels), logits

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    @jax.jit
    def step(params, opt, images, labels):
        (loss, logits), grads = grad_fn(params, images, labels)
        params, opt = adam_step(params, grads, opt, lr)
        return params, opt, loss, logits

    return step


def train(
    spec: ModelSpec,
    steps: int = 300,
    batch: int = 32,
    lr: float = 1e-3,
    seed: int = 42,
    ann: bool = False,
    log_every: int = 25,
    log: list | None = None,
) -> list[dict[str, Any]]:
    """Train on the synthetic dataset for ``spec``; returns final params."""
    gen = datasets.FOR_SPEC[spec.name if spec.name in datasets.FOR_SPEC else "tiny"]
    params = init_params(jax.random.PRNGKey(seed), spec)
    opt = adam_init(params)
    step_fn = make_ann_step(spec, lr) if ann else make_snn_step(spec, lr)

    t0 = time.time()
    for i in range(steps):
        imgs, labels = gen(seed, i * batch, batch)
        x = jnp.asarray(imgs, jnp.float32) / 255.0
        y = jnp.asarray(labels)
        params, opt, loss, logits = step_fn(params, opt, x, y)
        if i % log_every == 0 or i == steps - 1:
            acc = accuracy(np.asarray(logits), np.asarray(labels))
            line = (
                f"[{'ann' if ann else 'snn'}:{spec.name} T={spec.num_steps}] "
                f"step {i:4d} loss {float(loss):.4f} acc {acc:.3f} "
                f"({time.time() - t0:.1f}s)"
            )
            print(line, flush=True)
            if log is not None:
                log.append(dict(step=i, loss=float(loss), acc=acc))
    return params


def evaluate_train_view(
    params, spec: ModelSpec, count: int = 256, seed: int = 7, ann: bool = False
) -> float:
    """Held-out accuracy of the float training view."""
    gen = datasets.FOR_SPEC[spec.name if spec.name in datasets.FOR_SPEC else "tiny"]
    imgs, labels = gen(seed + 1000, 10_000_000, count)
    x = jnp.asarray(imgs, jnp.float32) / 255.0
    if ann:
        logits = forward_train_ann(params, spec, x)
    else:
        logits, _ = forward_train(params, spec, x)
    return accuracy(np.asarray(logits), labels)


def evaluate_deployed(params, spec: ModelSpec, count: int = 256, seed: int = 7) -> float:
    """Held-out accuracy of the deployed integer model (jnp oracle path)."""
    gen = datasets.FOR_SPEC[spec.name if spec.name in datasets.FOR_SPEC else "tiny"]
    imgs, labels = gen(seed + 1000, 10_000_000, count)
    d = deploy(params, spec)
    logits = forward_deployed_batched(
        d, spec, jnp.asarray(imgs, jnp.float32), use_pallas=False
    )
    return accuracy(np.asarray(logits), labels)


# --------------------------------------------------------------------------
# Fig. 8 sweep
# --------------------------------------------------------------------------


def fig8_sweep(
    base: str, steps: int, batch: int, t_values: tuple[int, ...] = (1, 2, 4, 6, 8)
) -> dict[str, Any]:
    """ANN vs binary-SNN accuracy across time steps (paper Fig. 8)."""
    make = SPECS[base]
    ann_spec = make(num_steps=1)
    ann_params = train(ann_spec, steps=steps, batch=batch, ann=True)
    ann_acc = evaluate_train_view(ann_params, ann_spec, ann=True)

    series = []
    for t in t_values:
        spec = make(num_steps=t)
        params = train(spec, steps=steps, batch=batch)
        acc = evaluate_train_view(params, spec)
        dep_acc = evaluate_deployed(params, spec)
        series.append(dict(T=t, snn_acc=acc, snn_deployed_acc=dep_acc))
        print(f"Fig8 {base}: T={t} snn={acc:.3f} deployed={dep_acc:.3f}", flush=True)
    result = dict(dataset=base, ann_acc=ann_acc, series=series)
    print(json.dumps(result, indent=2))
    return result


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--spec", default="tiny", choices=sorted(SPECS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--num-steps", type=int, default=None, help="override T")
    ap.add_argument("--out", default=None, help="write deployed .vsaw weights")
    ap.add_argument("--fig8", action="store_true", help="run the Fig. 8 sweep")
    ap.add_argument("--json-out", default=None, help="dump metrics as json")
    args = ap.parse_args()

    if args.fig8:
        result = fig8_sweep(args.spec, args.steps, args.batch)
        if args.json_out:
            with open(args.json_out, "w") as f:
                json.dump(result, f, indent=2)
        return

    make = SPECS[args.spec]
    spec = make(num_steps=args.num_steps) if args.num_steps else make()
    log: list = []
    params = train(spec, steps=args.steps, batch=args.batch, lr=args.lr, log=log)
    acc = evaluate_train_view(params, spec)
    dep_acc = evaluate_deployed(params, spec)
    print(f"final: train-view acc {acc:.3f}, deployed acc {dep_acc:.3f}")
    if args.out:
        params_io.save_deployed(args.out, deploy(params, spec), spec)
        print(f"wrote {args.out}")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(dict(loss_curve=log, acc=acc, deployed_acc=dep_acc), f, indent=2)


if __name__ == "__main__":
    main()

//! Multi-model serving demo: two models deployed in one registry, a
//! heterogeneous worker pool (golden + chip-sim) draining one queue,
//! mixed traffic that never shares a batch across models, and per-model
//! / per-backend telemetry read back from the metrics registry.
//!
//! ```sh
//! cargo run --release --example serve_snn
//! ```

use std::sync::Arc;
use std::time::Instant;
use vsa::config::{models, HwConfig};
use vsa::coordinator::{
    parse_pool, ChipEngine, Coordinator, CoordinatorConfig, EngineKind, GoldenEngine,
    InferenceEngine, ModelRegistry,
};
use vsa::data::synth;
use vsa::snn::params::DeployedModel;
use vsa::telemetry::Registry;
use vsa::util::stats::argmax;

const REQUESTS: usize = 96;

fn main() -> anyhow::Result<()> {
    // Deploy two models (synthesized weights — no artifacts needed).
    let mut registry = ModelRegistry::new();
    let tiny = registry.register("tiny", synthesize("tiny", 11)?)?;
    let mnist = registry.register("mnist", synthesize("mnist", 12)?)?;
    let registry = Arc::new(registry);

    // Heterogeneous pool from the same spec grammar `vsa serve --pool`
    // accepts: two golden workers plus one cycle-accurate chip-sim.
    let pool = parse_pool("golden:2,chip-sim:1")?;
    let cfg = CoordinatorConfig {
        workers: pool.len(),
        max_batch: 8,
        queue_depth: 64, // small queue => visible backpressure under load
        ..CoordinatorConfig::default()
    };
    println!(
        "starting coordinator: {} workers (golden:2,chip-sim:1), batch <= {}, queue {}",
        cfg.workers, cfg.max_batch, cfg.queue_depth
    );

    let reg = Arc::clone(&registry);
    let mut coord = Coordinator::start(cfg, Arc::clone(&registry), move |w| {
        let engine: Box<dyn InferenceEngine> = match pool[w] {
            EngineKind::Golden => Box::new(GoldenEngine::new(Arc::clone(&reg), 8)),
            EngineKind::ChipSim => {
                Box::new(ChipEngine::new(HwConfig::default(), Arc::clone(&reg), 8))
            }
        };
        engine
    });

    // Fire a burst of interleaved requests: even indices classify tiny
    // images, odd indices mnist images.  The batcher partitions by
    // model, so the two streams never share a batch.
    let tiny_samples = synth::tiny_like(5, 0, REQUESTS / 2);
    let mnist_samples = synth::mnist_like(5, 0, REQUESTS - REQUESTS / 2);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(REQUESTS);
    for i in 0..REQUESTS {
        let (model, s) = if i % 2 == 0 {
            (tiny, &tiny_samples[i / 2])
        } else {
            (mnist, &mnist_samples[i / 2])
        };
        rxs.push((s.label, coord.submit(model, s.image.clone())?));
    }

    // Every request resolves to a typed outcome: Ok(result) or a
    // ServeError (shed, engine failure after retries, panic).
    let mut correct = 0usize;
    let mut not_served = 0usize;
    for (label, rx) in rxs {
        match rx.recv()? {
            Ok(res) => {
                if argmax(&res.logits) == label {
                    correct += 1;
                }
            }
            Err(e) => {
                eprintln!("request not served: {e}");
                not_served += 1;
            }
        }
    }
    let wall = t0.elapsed();

    // Quiesce, then read the per-model / per-backend / cache telemetry.
    coord.drain();
    let treg = Registry::new();
    coord.export_into(&treg, "serve");
    let snap = treg.snapshot();
    let cache = coord.cache_totals();
    let stats = coord.shutdown();

    println!("\nserved {REQUESTS} requests in {:.1} ms", wall.as_secs_f64() * 1e3);
    println!("  throughput   {:.1} req/s", REQUESTS as f64 / wall.as_secs_f64());
    println!("  mean batch   {:.2} (of 8 max)", stats.mean_batch);
    println!(
        "  latency ms   p50 {:.2} / p95 {:.2} / p99 {:.2}",
        stats.latency_ms_p50, stats.latency_ms_p95, stats.latency_ms_p99
    );
    println!(
        "  outcomes     completed {} / failed {} / shed {}",
        stats.completed, stats.failed, stats.shed
    );
    for name in ["tiny", "mnist"] {
        let done = snap.counters.get(&format!("serve.model.{name}.completed")).unwrap_or(&0);
        println!("  model {name:<6} completed {done}");
    }
    for backend in ["golden", "chip-sim"] {
        let done = snap.counters.get(&format!("serve.backend.{backend}.completed")).unwrap_or(&0);
        let n = snap.counters.get(&format!("serve.backend.{backend}.workers")).unwrap_or(&0);
        println!("  backend {backend:<8} {n} worker(s), completed {done}");
    }
    println!(
        "  model cache  {} lookups / {} hits / {} packs / {} evictions",
        cache.lookups, cache.hits, cache.packs, cache.evictions
    );
    if not_served > 0 {
        println!("  ({not_served} requests got typed errors — see above)");
    }
    println!("  accuracy     {correct}/{REQUESTS} (untrained weights: ~chance)");
    Ok(())
}

fn synthesize(name: &str, seed: u64) -> anyhow::Result<DeployedModel> {
    let spec = models::by_name(name, 4).ok_or_else(|| anyhow::anyhow!("unknown preset {name}"))?;
    Ok(DeployedModel::synthesize(&spec, seed))
}

//! Serving demo: the rust coordinator batches concurrent classification
//! requests onto PJRT workers running the AOT-compiled JAX/Pallas module.
//! Python never runs here — the HLO artifact is loaded and executed
//! natively.  Falls back to the golden engine if artifacts are missing.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_snn
//! ```

use std::time::Instant;
use vsa::coordinator::{
    Coordinator, CoordinatorConfig, GoldenEngine, InferenceEngine, PjrtEngine,
};
use vsa::data::synth;
use vsa::runtime::{Manifest, PjrtExecutor};
use vsa::snn::Network;
use vsa::util::stats::argmax;

const REQUESTS: usize = 96;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let entry = manifest
        .find("mnist", 8)
        .ok_or_else(|| anyhow::anyhow!("mnist artifact missing — run `make artifacts`"))?
        .clone();
    let hlo = manifest.hlo_path(&entry);
    let weights = manifest.weights_path(&entry);

    let cfg = CoordinatorConfig {
        workers: 2,
        max_batch: entry.batch,
        queue_depth: 64, // small queue => visible backpressure under load
        ..CoordinatorConfig::default()
    };
    println!(
        "starting coordinator: {} workers, batch <= {}, queue {}",
        cfg.workers, cfg.max_batch, cfg.queue_depth
    );

    let coord = Coordinator::start(cfg, move |w| -> Box<dyn InferenceEngine> {
        match PjrtExecutor::load(&hlo, entry.batch, entry.in_channels, entry.in_size) {
            Ok(exe) => {
                if w == 0 {
                    println!("worker engines: PJRT ({})", exe.platform());
                }
                Box::new(PjrtEngine::new(exe))
            }
            Err(e) => {
                eprintln!("worker {w}: PJRT unavailable ({e:#}); using golden engine");
                let net = Network::from_vsaw_file(&weights).expect("weights");
                Box::new(GoldenEngine::new(net, entry.batch))
            }
        }
    });

    // Fire a burst of concurrent requests (the submission queue applies
    // backpressure if we outrun the workers).
    let samples = synth::mnist_like(5, 0, REQUESTS);
    let t0 = Instant::now();
    let rxs: Vec<_> = samples
        .iter()
        .map(|s| coord.submit(s.image.clone()))
        .collect::<Result<_, _>>()?;

    // Since PR6 every request resolves to a typed outcome: Ok(result) or
    // a ServeError (shed, engine failure after retries, panic).
    let mut correct = 0usize;
    let mut not_served = 0usize;
    for (rx, s) in rxs.into_iter().zip(&samples) {
        match rx.recv()? {
            Ok(res) => {
                if argmax(&res.logits) == s.label {
                    correct += 1;
                }
            }
            Err(e) => {
                eprintln!("request not served: {e}");
                not_served += 1;
            }
        }
    }
    let wall = t0.elapsed();
    let stats = coord.shutdown();

    println!("\nserved {REQUESTS} requests in {:.1} ms", wall.as_secs_f64() * 1e3);
    println!("  throughput   {:.1} req/s", REQUESTS as f64 / wall.as_secs_f64());
    println!("  mean batch   {:.2} (of {} max)", stats.mean_batch, entry.batch);
    println!(
        "  latency ms   p50 {:.2} / p95 {:.2} / p99 {:.2}",
        stats.latency_ms_p50, stats.latency_ms_p95, stats.latency_ms_p99
    );
    println!(
        "  outcomes     completed {} / failed {} / shed {}",
        stats.completed, stats.failed, stats.shed
    );
    if not_served > 0 {
        println!("  ({not_served} requests got typed errors — see above)");
    }
    println!("  accuracy     {correct}/{REQUESTS} (untrained weights: ~chance)");
    Ok(())
}

//! Quickstart: load a deployed model and classify synthetic samples with
//! the pure-rust golden engine — no python, no simulator.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use vsa::data::synth;
use vsa::snn::Network;
use vsa::util::stats::argmax;

fn main() -> anyhow::Result<()> {
    // 1. Load the binary-weight SNN exported by the python compile path.
    let net = Network::from_vsaw_file("artifacts/mnist_t8.vsaw")?;
    println!(
        "loaded '{}': {} layers, T = {} time steps",
        net.model.name,
        net.model.layers.len(),
        net.model.num_steps
    );

    // 2. Generate a few deterministic synthetic samples (MNIST-shaped).
    let samples = synth::mnist_like(42, 0, 8);

    // 3. Classify.  `infer_u8` runs the full spiking pipeline: encoding
    //    layer (multi-bit -> spikes), spiking convs with IF-BN neurons,
    //    pooling, spiking fc, and the accumulating readout.
    for (i, s) in samples.iter().enumerate() {
        let logits = net.infer_u8(&s.image);
        println!(
            "sample {i}: label={} predicted={} logits={:?}",
            s.label,
            argmax(&logits),
            logits
        );
    }

    // 4. Inspect spiking activity with the traced API.
    let (_, trace) = net.infer_traced(&samples[0].image);
    for (li, train) in trace.spike_trains.iter().enumerate() {
        let spikes: u64 = train.iter().map(|m| m.total_spikes()).sum();
        let neurons = train[0].channels() * train[0].height() * train[0].width();
        println!(
            "layer {li}: {spikes} spikes over T={} ({:.1}% firing rate)",
            train.len(),
            100.0 * spikes as f64 / (neurons * train.len()) as f64
        );
    }
    Ok(())
}

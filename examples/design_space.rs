//! Design-space exploration walkthrough — making the paper's
//! "reconfigurable" claim executable at scale: sweep the chip's knobs,
//! extract the (throughput, power, area) Pareto frontier, and see where
//! the published design point lands.  Needs no artifacts: candidates are
//! scored by the analytic timing model (`Chip::analyze`), which charges
//! the exact counters of a functional run without executing the datapath.
//!
//! ```sh
//! cargo run --release --example design_space
//! ```

use vsa::config::HwConfig;
use vsa::dse::{self, Candidate, SearchSpace};
use vsa::energy::area;

fn main() -> anyhow::Result<()> {
    // --- 1. a declarative space over every reconfigurable knob ------------
    let space = SearchSpace::small();
    let workloads = ["mnist", "cifar10"];
    println!("== space '{}': {} grid points", space.name, space.len());

    // Validity filtering: points the timing model would mis-represent
    // (conv weights that cannot stay resident, spike planes overflowing a
    // ping-pong bank, PE arrays too skinny for a 3x3 kernel, fusion with
    // no fusible pair) are rejected before evaluation.
    let candidates: Vec<Candidate> = space
        .cartesian()
        .filter(|c| dse::validate(c, &workloads).is_ok())
        .collect();
    println!("   {} candidates valid for {:?}", candidates.len(), workloads);

    // --- 2. evaluate every candidate on both Table-I workloads -----------
    let t0 = std::time::Instant::now();
    let results = dse::evaluate_all(&candidates, &workloads, 4);
    println!(
        "   evaluated in {:.1} ms on 4 threads (analytic model: no inference runs)",
        t0.elapsed().as_secs_f64() * 1e3
    );

    // --- 3. Pareto frontier over (throughput, power, area) ---------------
    let front = dse::frontier(&results);
    print!("\n{}", dse::report::render(&results, &front, 3));

    // --- 4. where the published design point lands ------------------------
    // Chip-vs-chip optimality is judged at the paper's T = 8: lower-T
    // candidates do strictly less compute and dominate trivially while
    // paying an accuracy cost the analytic model does not score.
    let slack = dse::paper_slack_at_t(&results).expect("paper point is in the space");
    println!(
        "\npaper design point [{}]: slack {:.4} vs the T=8 frontier \
         (<= 0 means Pareto-optimal; ties pin it at 0)",
        Candidate::paper().id(),
        slack
    );

    // --- 5. single-knob sensitivity: PE blocks ----------------------------
    println!("\n== PE-block sensitivity at the design point (cifar10, T=8)");
    println!("{:>8} {:>8} {:>12} {:>10} {:>10}", "blocks", "PEs", "inf/s", "mW", "KGE");
    for blocks in [8, 16, 32, 64] {
        let hw = HwConfig { pe_blocks: blocks, ..HwConfig::default() };
        let cand = Candidate { hw, num_steps: 8 };
        let r = dse::evaluate_one(&cand, &["cifar10"]);
        println!(
            "{:>8} {:>8} {:>12.1} {:>10.3} {:>10.1}",
            blocks,
            cand.hw.total_pes(),
            r.throughput_ips,
            r.power_mw,
            area::total_area_kge(&cand.hw)
        );
    }
    println!("\n(the frontier JSON report comes from `vsa dse`; see README)");
    Ok(())
}

//! End-to-end driver: run the full CIFAR-10 network (paper Table I) on the
//! cycle-accurate VSA simulator and report every headline metric of the
//! paper's evaluation — throughput, utilization, latency, DRAM traffic
//! with/without fusion, core power, and the Table III efficiency figures.
//! Results are cross-checked against the golden model on every sample.
//!
//! ```sh
//! make artifacts && cargo run --release --example accelerator_sim
//! ```

use vsa::arch::{Chip, SimMode};
use vsa::config::HwConfig;
use vsa::data::synth;
use vsa::energy::{area, power, report};
use vsa::snn::Network;
use vsa::util::stats::argmax;

fn main() -> anyhow::Result<()> {
    let net = Network::from_vsaw_file("artifacts/cifar10_t8.vsaw")?;
    let hw = HwConfig::default();
    println!(
        "VSA chip: {} PEs @ {} MHz, {:.4} KB SRAM, peak {:.0} GOPS",
        hw.total_pes(),
        hw.freq_mhz,
        hw.total_sram_kb(),
        hw.peak_gops()
    );

    // --- batch of real inferences through the cycle-accurate model ------
    let samples = synth::cifar_like(7, 0, 4);
    let chip = Chip::new(hw.clone(), SimMode::Fast);
    let mut last = None;
    for (i, s) in samples.iter().enumerate() {
        let r = chip.run(&net.model, &s.image);
        // spike-exact cross-check against the golden model
        assert_eq!(r.logits, net.infer_u8(&s.image), "sim diverged on sample {i}");
        println!(
            "sample {i}: pred={} cycles={} latency={:.1}us eff={:.0} GOPS util={:.1}%",
            argmax(&r.logits),
            r.cycles,
            r.latency_us,
            r.gops,
            r.utilization * 100.0
        );
        last = Some(r);
    }
    let r = last.unwrap();

    // --- per-layer profile ----------------------------------------------
    println!("\nper-layer profile (last sample):");
    for (i, l) in r.layers.iter().enumerate() {
        println!(
            "  L{i:<2} {:?}: {:>9} cycles  util {:>5.1}%  spikes {}",
            l.kind,
            l.cycles,
            l.utilization * 100.0,
            l.spikes_emitted
        );
    }

    // --- DRAM traffic & fusion -------------------------------------------
    let off = Chip::new(
        HwConfig { layer_fusion: false, ..hw.clone() },
        SimMode::Fast,
    )
    .run(&net.model, &samples[0].image);
    let on_kb = r.dram.total() as f64 / 1024.0;
    let off_kb = off.dram.total() as f64 / 1024.0;
    println!(
        "\nDRAM per inference: {off_kb:.1} KB -> {on_kb:.1} KB with fusion \
         ({:.1}% saved)",
        (1.0 - on_kb / off_kb) * 100.0
    );
    println!("paper: 1450.172 KB -> 938.172 KB (35.3% saved)");

    // --- Table III summary -----------------------------------------------
    let core_mw = power::core_power_mw(&hw, &r);
    let kge = area::logic_area(&hw).total();
    println!("\nTable III (this work, measured on CIFAR-10):");
    println!("  logic area      {kge:.2} KGE        (paper 114.98)");
    println!("  core power      {core_mw:.3} mW     (paper 88.968)");
    println!(
        "  power eff.      {:.1} TOPS/W   (paper 25.9)",
        power::power_efficiency_tops_w(&hw, core_mw)
    );
    println!(
        "  area eff.       {:.3} GOPS/KGE (paper 20.038)",
        hw.peak_gops() / kge
    );
    let row = report::this_work(&hw, &r);
    println!("\n{}", report::render_table3(&[row]));
    Ok(())
}

//! End-to-end train -> deploy -> serve pipeline validation.
//!
//! Consumes the checkpoint produced by `make train` (STBP training of the
//! tiny model on the synthetic corpus, a few hundred steps, loss curve in
//! `artifacts/tiny_train_log.json`), then:
//!
//! 1. prints the training loss curve (L2's STBP actually learned);
//! 2. evaluates the *deployed integer* model (golden engine) on held-out
//!    synthetic data and compares against the untrained baseline;
//! 3. runs the trained model through the cycle-accurate chip simulator;
//! 4. serves it through the coordinator.
//!
//! ```sh
//! make train && cargo run --release --example e2e_train_deploy
//! ```

use std::sync::Arc;
use vsa::arch::{Chip, SimMode};
use vsa::config::json::Json;
use vsa::config::HwConfig;
use vsa::coordinator::{
    Coordinator, CoordinatorConfig, GoldenEngine, InferenceEngine, ModelRegistry,
};
use vsa::data::synth;
use vsa::snn::Network;
use vsa::util::stats::argmax;

const HELDOUT: usize = 200;
/// Must match compile/train.py::evaluate_deployed (seed + 1000, start 1e7).
const EVAL_SEED: u64 = 7 + 1000;
const EVAL_START: u64 = 10_000_000;

fn accuracy(net: &Network, seed: u64, start: u64, n: usize) -> f64 {
    let samples = synth::tiny_like(seed, start, n);
    let correct = samples
        .iter()
        .filter(|s| argmax(&net.infer_u8(&s.image)) == s.label)
        .count();
    correct as f64 / n as f64
}

fn main() -> anyhow::Result<()> {
    let trained_path = "artifacts/tiny_trained.vsaw";
    if !std::path::Path::new(trained_path).exists() {
        eprintln!("{trained_path} missing — run `make train` first");
        std::process::exit(1);
    }

    // --- 1. loss curve -----------------------------------------------------
    if let Ok(text) = std::fs::read_to_string("artifacts/tiny_train_log.json") {
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        if let Some(curve) = v.get("loss_curve").and_then(Json::as_arr) {
            println!("STBP training loss curve (tiny, synthetic corpus):");
            for p in curve {
                println!(
                    "  step {:>4}  loss {:.4}  batch-acc {:.3}",
                    p.get("step").and_then(Json::as_i64).unwrap_or(-1),
                    p.get("loss").and_then(Json::as_f64).unwrap_or(f64::NAN),
                    p.get("acc").and_then(Json::as_f64).unwrap_or(f64::NAN),
                );
            }
        }
    }

    // --- 2. deployed accuracy: trained vs untrained -------------------------
    let trained = Network::from_vsaw_file(trained_path)?;
    let untrained = Network::from_vsaw_file("artifacts/tiny_t4.vsaw")?;
    let acc_trained = accuracy(&trained, EVAL_SEED, EVAL_START, HELDOUT);
    let acc_untrained = accuracy(&untrained, EVAL_SEED, EVAL_START, HELDOUT);
    println!("\nheld-out deployed accuracy ({HELDOUT} samples):");
    println!("  untrained (random binary weights): {acc_untrained:.3}");
    println!("  trained (STBP + IF-BN folding):    {acc_trained:.3}");
    anyhow::ensure!(
        acc_trained > acc_untrained + 0.15 && acc_trained > 0.3,
        "training did not beat the untrained baseline"
    );

    // --- 3. run the trained model on the chip -------------------------------
    let img = &synth::tiny_like(EVAL_SEED, EVAL_START, 1)[0];
    let r = Chip::new(HwConfig::default(), SimMode::Fast).run(&trained.model, &img.image);
    assert_eq!(r.logits, trained.infer_u8(&img.image));
    println!(
        "\nchip simulation of the trained model: {} cycles, {:.1} us, {:.0} GOPS eff",
        r.cycles, r.latency_us, r.gops
    );

    // --- 4. serve it ---------------------------------------------------------
    let (reg, m) = ModelRegistry::single(trained.model.clone());
    let regc = Arc::clone(&reg);
    let coord = Coordinator::start(CoordinatorConfig::default(), reg, move |_| {
        Box::new(GoldenEngine::new(Arc::clone(&regc), 8)) as Box<dyn InferenceEngine>
    });
    let samples = synth::tiny_like(EVAL_SEED, EVAL_START, 64);
    let rxs: Vec<_> = samples
        .iter()
        .map(|s| coord.submit(m, s.image.clone()))
        .collect::<Result<_, _>>()?;
    let correct = rxs
        .into_iter()
        .zip(&samples)
        .filter(|(rx, s)| {
            rx.recv().map(|r| argmax(&r.logits) == s.label).unwrap_or(false)
        })
        .count();
    let stats = coord.shutdown();
    println!(
        "served 64 requests: {:.1} req/s, p50 {:.2} ms, accuracy {}/64",
        stats.throughput_rps, stats.latency_ms_p50, correct
    );
    println!("\ne2e train->deploy->simulate->serve pipeline OK");
    Ok(())
}

//! Layer-fusion DRAM study (paper §IV-B) across all three models, plus the
//! tick-batching ablation (what DRAM traffic would look like if membrane
//! potentials round-tripped off-chip every time step, the cost SpinalFlow's
//! analysis highlights).
//!
//! ```sh
//! make artifacts && cargo run --release --example layer_fusion_study
//! ```

use vsa::arch::dram::{Dram, Traffic};
use vsa::arch::fusion::plan_fusion;
use vsa::arch::schedule::{layer_dram, plan_model};
use vsa::arch::{Chip, SimMode};
use vsa::config::HwConfig;
use vsa::data::synth;
use vsa::snn::Network;

fn main() -> anyhow::Result<()> {
    println!("{:<10} {:>14} {:>14} {:>9}", "model", "no-fusion KB", "fusion KB", "saved");
    // One chip per fusion setting, reused across the model sweep (the
    // PR5 packed-model cache makes repeat runs pack-free).
    let chip_on = Chip::new(HwConfig::default(), SimMode::Fast);
    let chip_off = Chip::new(
        HwConfig { layer_fusion: false, ..HwConfig::default() },
        SimMode::Fast,
    );
    for name in ["tiny", "mnist", "cifar10"] {
        let path = match name {
            "tiny" => "artifacts/tiny_t4.vsaw",
            "mnist" => "artifacts/mnist_t8.vsaw",
            _ => "artifacts/cifar10_t8.vsaw",
        };
        let net = Network::from_vsaw_file(path)?;
        let img = &synth::for_model(name, 3, 0, 1)[0].image;

        let on = chip_on.run(&net.model, img);
        let off = chip_off.run(&net.model, img);
        let on_kb = on.dram.total() as f64 / 1024.0;
        let off_kb = off.dram.total() as f64 / 1024.0;
        println!(
            "{name:<10} {off_kb:>14.3} {on_kb:>14.3} {:>8.1}%",
            (1.0 - on_kb / off_kb) * 100.0
        );
    }
    println!("\npaper (CIFAR-10): 1450.172 KB -> 938.172 KB  (35.3% saved)\n");

    // --- which pairs actually fuse on CIFAR-10? --------------------------
    let net = Network::from_vsaw_file("artifacts/cifar10_t8.vsaw")?;
    let hw = HwConfig::default();
    let plans = plan_model(&net.model);
    let groups = plan_fusion(&plans, &hw);
    println!("CIFAR-10 fusion plan (weight SRAM budget {:.0} KB):", hw.weight_sram_kb);
    for g in &groups {
        let names: Vec<String> = (g.start..g.start + g.len)
            .map(|i| format!("{:?}({}ch)", plans[i].kind, plans[i].c_out))
            .collect();
        let bits: u64 = (g.start..g.start + g.len).map(|i| plans[i].weight_bits()).sum();
        println!(
            "  {}  [{:.1} KB weights]{}",
            names.join(" + "),
            bits as f64 / 8.0 / 1024.0,
            if g.len == 2 { "  <- fused" } else { "" }
        );
    }

    // --- tick-batching ablation ------------------------------------------
    let t = net.model.num_steps;
    let mut with_tb = Dram::default();
    let mut without_tb = Dram::default();
    for plan in &plans {
        layer_dram(plan, t, false, false, true, &mut with_tb);
        layer_dram(plan, t, false, false, false, &mut without_tb);
    }
    println!(
        "\ntick batching (no fusion): {:.1} KB vs {:.1} KB without ({:.1}x), \
         membrane alone {:.1} KB",
        with_tb.total() as f64 / 1024.0,
        without_tb.total() as f64 / 1024.0,
        without_tb.total() as f64 / with_tb.total() as f64,
        without_tb.category(Traffic::Membrane) as f64 / 1024.0
    );
    Ok(())
}

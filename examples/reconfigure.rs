//! Reconfigurability demo — the paper's titular claim: ONE accelerator
//! runs different models, different inference time steps, multi-bit
//! encoding or pure spiking input, and different PE geometries, with no
//! change to the datapath.  (Contrast: the BW-SNN baseline is a fixed
//! 5-conv ASIC; see `vsa::baselines::bwsnn::fits`.)
//!
//! ```sh
//! make artifacts && cargo run --release --example reconfigure
//! ```

use vsa::arch::{Chip, SimMode};
use vsa::baselines::bwsnn::{self, BwSnnConfig};
use vsa::config::HwConfig;
use vsa::data::synth;
use vsa::snn::Network;

fn main() -> anyhow::Result<()> {
    // --- one chip, three models -------------------------------------------
    println!("== same chip, different models");
    println!(
        "{:<10} {:>6} {:>12} {:>12} {:>10} {:>8}",
        "model", "T", "cycles", "latency us", "eff GOPS", "util %"
    );
    // ONE chip instance across every run below: its packed-model cache
    // (PR5) means repeated images re-pack nothing, exactly like loading
    // the weight SRAM once.
    let chip = Chip::new(HwConfig::default(), SimMode::Fast);
    for (name, path) in [
        ("tiny", "artifacts/tiny_t4.vsaw"),
        ("mnist", "artifacts/mnist_t8.vsaw"),
        ("cifar10", "artifacts/cifar10_t8.vsaw"),
    ] {
        let net = Network::from_vsaw_file(path)?;
        let img = &synth::for_model(name, 1, 0, 1)[0].image;
        let r = chip.run(&net.model, img);
        println!(
            "{name:<10} {:>6} {:>12} {:>12.1} {:>10.0} {:>8.1}",
            net.model.num_steps,
            r.cycles,
            r.latency_us,
            r.gops,
            r.utilization * 100.0
        );
    }

    // --- one model, different time steps ----------------------------------
    println!("\n== same model, reconfigured time steps (mnist)");
    let net = Network::from_vsaw_file("artifacts/mnist_t8.vsaw")?;
    let img = &synth::mnist_like(1, 0, 1)[0].image;
    println!("{:>3} {:>12} {:>12} {:>14}", "T", "cycles", "latency us", "DRAM KB");
    for t in [1, 2, 4, 8] {
        let mut model = net.model.clone();
        model.num_steps = t;
        // T is read live by the simulator: the whole sweep reuses the
        // weights packed on the first run (no re-pack per T).
        let r = chip.run(&model, img);
        println!(
            "{t:>3} {:>12} {:>12.1} {:>14.1}",
            r.cycles,
            r.latency_us,
            r.dram.total() as f64 / 1024.0
        );
    }

    // --- different PE geometries -------------------------------------------
    println!("\n== same model, reconfigured PE fabric (cifar10)");
    let net = Network::from_vsaw_file("artifacts/cifar10_t8.vsaw")?;
    let img = &synth::cifar_like(1, 0, 1)[0].image;
    println!(
        "{:>9} {:>6} {:>12} {:>12} {:>8}",
        "blocks", "PEs", "cycles", "latency us", "util %"
    );
    let mut logits_ref = None;
    for blocks in [8, 16, 32, 64] {
        let hw = HwConfig { pe_blocks: blocks, ..HwConfig::default() };
        let r = Chip::new(hw.clone(), SimMode::Fast).run(&net.model, img);
        // results must be configuration-independent
        if let Some(l) = &logits_ref {
            assert_eq!(&r.logits, l);
        } else {
            logits_ref = Some(r.logits.clone());
        }
        println!(
            "{blocks:>9} {:>6} {:>12} {:>12.1} {:>8.1}",
            hw.total_pes(),
            r.cycles,
            r.latency_us,
            r.utilization * 100.0
        );
    }

    // --- the fixed-function contrast ---------------------------------------
    println!("\n== BW-SNN-style fixed 5-conv ASIC feasibility");
    for (name, path) in [
        ("tiny", "artifacts/tiny_t4.vsaw"),
        ("mnist", "artifacts/mnist_t8.vsaw"),
        ("cifar10", "artifacts/cifar10_t8.vsaw"),
    ] {
        let net = Network::from_vsaw_file(path)?;
        match bwsnn::fits(&BwSnnConfig::default(), &net.model) {
            Ok(()) => println!("  {name}: fits the fixed pipeline"),
            Err(e) => println!("  {name}: REJECTED — {e:?}"),
        }
    }
    println!("  (VSA runs all three — the reconfigurability of Table III)");
    Ok(())
}
